"""AnalyticsService: streaming read-out parity, admission control, and
the unified envelope.

The serving answers are parity-checked against offline ``run_query`` for
every query kind (same graph, same sources -> bit-identical arrays); the
mid-sweep streaming read-outs must land khop/reach answers EARLIER than
lane flush while staying bit-identical to the flush-time answer (BFS
depth finality). The admission front door (bounded queue + per-tenant
quota), the REJECTED/QUEUED/RUNNING/DONE lifecycle, the worker-thread
submit/result path, the envelope wire codec, and the QueryMeta
deprecation shim are covered as units. A forced multi-device leg
(conftest subprocess pattern, ndev in {2, 4}) pins the sharded service
bit-identical to the host offline path.
"""
import warnings

import numpy as np
import pytest
from conftest import run_in_subprocess

from repro.analytics import (BFSQuery, ClosenessQuery, ComponentsQuery,
                             DiameterQuery, KHopQuery, LaneEngine,
                             ReachQuery, SSSPQuery, run_query)
from repro.analytics.api import (AnalyticsRequest, QUERY_KINDS, QUERY_TYPES,
                                 query_kind)
from repro.core.csr import from_edges
from repro.graph.generator import rmat_graph, rmat_weighted_graph
from repro.serving import (AdmissionController, AnalyticsService, DONE,
                           QUEUED, REJECTED, RUNNING, ServiceConfig,
                           parse_mix, synthetic_trace)


def path_graph(n):
    return from_edges(np.arange(n - 1), np.arange(1, n), n)


@pytest.fixture(scope="module")
def wg():
    """Small weighted R-MAT graph: serves every query kind."""
    return rmat_weighted_graph(8, 8, seed=1)


@pytest.fixture(scope="module")
def offline(wg):
    """The reference engine the service answers are checked against."""
    return LaneEngine(wg)


# ---------------------------------------------------------------------------
# Service vs run_query parity — every query kind, one service instance.
# ---------------------------------------------------------------------------

def test_service_answers_match_run_query_per_kind(wg, offline):
    queries = {
        "bfs": BFSQuery(sources=(0, 3, 5)),
        "khop": KHopQuery(sources=(1, 2), k=2),
        "reach": ReachQuery(sources=(0, 1), targets=(2, 3)),
        "closeness": ClosenessQuery(sources=(0, 1, 2, 3), chunk=4),
        "sssp": SSSPQuery(sources=(0, 4)),
        "components": ComponentsQuery(batch=32),
        "diameter": DiameterQuery(num_seeds=2, seed=0),
    }
    svc = AnalyticsService(wg, slots=16, sssp_slots=8)
    recs = {k: svc.submit(q) for k, q in queries.items()}
    svc.run_until_idle()
    for k, rec in recs.items():
        assert rec.status == DONE, k
        assert rec.answer.meta.kind == k
        assert rec.sojourn >= 1, "layer-clock sojourn must be positive"
    ref = {k: run_query(offline, q) for k, q in queries.items()}

    got = {k: recs[k].answer.result for k in queries}
    np.testing.assert_array_equal(got["bfs"].depth, ref["bfs"].depth)
    np.testing.assert_array_equal(got["bfs"].num_layers,
                                  ref["bfs"].num_layers)
    np.testing.assert_array_equal(got["khop"].words, ref["khop"].words)
    np.testing.assert_array_equal(got["khop"].counts, ref["khop"].counts)
    np.testing.assert_array_equal(got["reach"].hops, ref["reach"].hops)
    np.testing.assert_allclose(got["closeness"].closeness,
                               ref["closeness"].closeness, rtol=1e-12)
    assert got["closeness"].method == ref["closeness"].method
    np.testing.assert_array_equal(got["sssp"].dist, ref["sssp"].dist)
    assert got["sssp"].delta == ref["sssp"].delta
    np.testing.assert_array_equal(got["components"].labels,
                                  ref["components"].labels)
    assert got["diameter"].lower == ref["diameter"].lower
    assert got["diameter"].upper == ref["diameter"].upper


def test_foreign_delta_sssp_takes_batch_path(wg, offline):
    """An sssp request whose bucket width differs from the service's
    pinned delta can't ride the compiled tropical pool — it must fall
    back to the inline batch path and still answer exactly."""
    svc = AnalyticsService(wg, sssp_slots=8)
    foreign = float(svc.delta) * 3.0
    rec = svc.submit(SSSPQuery(sources=(2,), delta=foreign))
    assert rec.engine == "batch"
    svc.run_until_idle()
    ref = run_query(offline, SSSPQuery(sources=(2,), delta=foreign))
    np.testing.assert_array_equal(rec.answer.result.dist, ref.dist)
    assert rec.answer.result.delta == foreign


def test_sssp_on_unweighted_service_raises():
    svc = AnalyticsService(rmat_graph(6, 4, seed=0))
    with pytest.raises(ValueError, match="WeightedCSRGraph"):
        svc.submit(SSSPQuery(sources=(0,)))


# ---------------------------------------------------------------------------
# Streaming read-outs: early AND bit-identical (the depth-finality unlock).
# ---------------------------------------------------------------------------

def test_streaming_khop_answers_early_and_bit_identical():
    g = path_graph(64)
    q = KHopQuery(sources=(0,), k=2)
    stream = AnalyticsService(g, slots=4, streaming=True)
    flush = AnalyticsService(g, slots=4, streaming=False)
    r_s = stream.submit(AnalyticsRequest(query=q, id="s"))
    r_f = flush.submit(AnalyticsRequest(query=q, id="f"))
    stream.run_until_idle()
    flush.run_until_idle()
    assert r_s.answered_early and not r_f.answered_early
    # a depth-2 band on a 64-path is final ~60 layers before lane flush
    assert r_f.sojourn - r_s.sojourn >= 1
    a, b = r_s.answer.result, r_f.answer.result
    np.testing.assert_array_equal(a.words, b.words)
    np.testing.assert_array_equal(a.counts, b.counts)
    ref = run_query(g, q)
    np.testing.assert_array_equal(a.words, ref.words)
    np.testing.assert_array_equal(a.counts, ref.counts)
    np.testing.assert_array_equal(a.members(0), ref.members(0))


def test_streaming_reach_answers_on_target_discovery():
    g = path_graph(64)
    q = ReachQuery(sources=(0,), targets=(3,))
    svc = AnalyticsService(g, slots=4, streaming=True)
    rec = svc.submit(q)
    svc.run_until_idle()
    assert rec.answered_early
    assert rec.answer.result.hops[0, 0] == 3
    # vertex 3 is discovered at layer 3; the lane itself runs to 63
    assert rec.sojourn <= 8
    ref = run_query(g, q)
    np.testing.assert_array_equal(rec.answer.result.hops, ref.hops)


def test_streaming_retire_returns_capacity_to_pool():
    """An early-answered lane must actually retire: a second khop request
    that didn't fit the pool at submit dispatches after the retire,
    without waiting for the first lane's natural flush."""
    g = path_graph(64)
    svc = AnalyticsService(g, lanes=1, slots=4, streaming=True)
    r1 = svc.submit(KHopQuery(sources=(0,), k=1))
    r2 = svc.submit(KHopQuery(sources=(0,), k=1))
    svc.run_until_idle()
    assert r1.status == DONE and r2.status == DONE
    assert r1.answered_early and r2.answered_early
    # both answered from streamed bands long before a 64-layer flush
    assert max(r1.answer_layer, r2.answer_layer) < 32


# ---------------------------------------------------------------------------
# Admission control + lifecycle.
# ---------------------------------------------------------------------------

def test_admission_controller_bounded_queue():
    adm = AdmissionController(max_pending=2)
    assert adm.admit("a") == (True, None)
    assert adm.admit("a") == (True, None)
    ok, reason = adm.admit("a")
    assert not ok and "queue full" in reason
    assert adm.rejected == 1
    adm.on_dispatch("a")              # one leaves the queue
    assert adm.admit("a") == (True, None)


def test_admission_controller_tenant_quota():
    adm = AdmissionController(max_pending=8, tenant_quota=1)
    assert adm.admit("a") == (True, None)
    ok, reason = adm.admit("a")
    assert not ok and "quota" in reason and "'a'" in reason
    assert adm.admit("b") == (True, None)   # other tenants unaffected
    adm.on_dispatch("a")
    ok, _ = adm.admit("a")
    assert not ok, "quota spans QUEUED + RUNNING, not just the queue"
    adm.on_done("a")
    assert adm.admit("a") == (True, None)
    assert adm.inflight("a") == 1


def test_service_rejects_over_max_pending(wg):
    svc = AnalyticsService(wg, max_pending=1)
    r1 = svc.submit(BFSQuery(sources=(0,)))
    r2 = svc.submit(BFSQuery(sources=(1,)))
    assert r1.status == QUEUED
    assert r2.status == REJECTED and "queue full" in r2.reason
    svc.run_until_idle()
    assert r1.status == DONE
    assert r2.status == REJECTED, "rejection is terminal"
    stats = svc.stats()
    assert stats["done"] == 1 and stats["rejected"] == 1


def test_service_tenant_quota_releases_after_done(wg):
    svc = AnalyticsService(wg, tenant_quota=1)
    r1 = svc.submit(AnalyticsRequest(query=BFSQuery(sources=(0,)),
                                     tenant="t0"))
    r2 = svc.submit(AnalyticsRequest(query=BFSQuery(sources=(1,)),
                                     tenant="t0"))
    r3 = svc.submit(AnalyticsRequest(query=BFSQuery(sources=(2,)),
                                     tenant="t1"))
    assert r2.status == REJECTED and "quota" in r2.reason
    assert r3.status == QUEUED
    svc.run_until_idle()
    assert r1.status == DONE and r3.status == DONE
    r4 = svc.submit(AnalyticsRequest(query=BFSQuery(sources=(3,)),
                                     tenant="t0"))
    assert r4.status == QUEUED, "quota released once the request is DONE"


def test_lifecycle_transitions_and_poll():
    g = path_graph(32)
    svc = AnalyticsService(g, slots=4)
    rec = svc.submit(BFSQuery(sources=(0,)))
    rid = rec.request.id
    assert svc.poll(rid) == QUEUED
    svc.step()
    assert svc.poll(rid) == RUNNING     # a 32-path takes ~32 layers
    while svc.busy():
        svc.step()
    assert svc.poll(rid) == DONE
    assert rec.dispatch_layer >= rec.submit_layer
    assert rec.answer_layer > rec.dispatch_layer


def test_duplicate_request_id_raises(wg):
    svc = AnalyticsService(wg)
    svc.submit(AnalyticsRequest(query=BFSQuery(sources=(0,)), id="dup"))
    with pytest.raises(ValueError, match="duplicate request id"):
        svc.submit(AnalyticsRequest(query=BFSQuery(sources=(1,)),
                                    id="dup"))


def test_epoch_recycle_under_tight_slots():
    """More root demand than one epoch holds: the pool must drain and
    recycle its slots (epochs advance) and still answer everything."""
    g = path_graph(16)
    svc = AnalyticsService(g, slots=2)
    recs = [svc.submit(BFSQuery(sources=(i,))) for i in range(5)]
    svc.run_until_idle()
    assert all(r.status == DONE for r in recs)
    assert svc._packed.epochs >= 2
    ref = run_query(g, BFSQuery(sources=(4,)))
    np.testing.assert_array_equal(recs[4].answer.result.depth, ref.depth)


# ---------------------------------------------------------------------------
# Async front door (worker thread).
# ---------------------------------------------------------------------------

def test_threaded_submit_result_roundtrip(wg, offline):
    with AnalyticsService(wg, slots=16) as svc:
        rec = svc.submit(KHopQuery(sources=(3,), k=2))
        ans = svc.result(rec.request.id, timeout=120.0)
    ref = run_query(offline, KHopQuery(sources=(3,), k=2))
    np.testing.assert_array_equal(ans.result.counts, ref.counts)
    np.testing.assert_array_equal(ans.result.words, ref.words)


def test_result_without_worker_thread_raises(wg):
    svc = AnalyticsService(wg)
    rec = svc.submit(BFSQuery(sources=(0,)))
    with pytest.raises(RuntimeError, match="worker thread"):
        svc.result(rec.request.id)


def test_result_of_rejected_request_raises(wg):
    svc = AnalyticsService(wg, max_pending=1)
    svc.submit(BFSQuery(sources=(0,)))
    rec = svc.submit(BFSQuery(sources=(1,)))   # over the bound: REJECTED
    assert rec.status == REJECTED
    with svc:                                  # rejection is terminal —
        with pytest.raises(RuntimeError, match="rejected"):
            svc.result(rec.request.id, timeout=5.0)


# ---------------------------------------------------------------------------
# Replay + trace + mix parsing.
# ---------------------------------------------------------------------------

def test_replay_mixed_trace_answers_everything(wg):
    trace = synthetic_trace(wg.n, 12, mix="bfs:2,khop:2,reach:1,sssp:1",
                            seed=3, tenants=("t0", "t1"))
    svc = AnalyticsService(wg, slots=16, sssp_slots=8)
    stats = svc.replay(trace)
    assert stats["requests"] == 12 and stats["done"] == 12
    assert stats["rejected"] == 0
    assert set(stats["per_type"]) <= set(QUERY_KINDS)
    assert stats["sojourn_layers"]["p50"] >= 1
    for env in trace:
        rec = svc.record(env.id)
        assert rec.status == DONE
        ref = run_query(wg, env.query)
        if rec.kind == "sssp":
            np.testing.assert_array_equal(rec.answer.result.dist, ref.dist)
        elif rec.kind == "khop":
            np.testing.assert_array_equal(rec.answer.result.words,
                                          ref.words)


def test_parse_mix_normalizes_and_rejects_unknown_tags():
    w = parse_mix("bfs:3, khop:1")
    assert w == {"bfs": 0.75, "khop": 0.25}
    assert parse_mix("sssp") == {"sssp": 1.0}
    with pytest.raises(ValueError, match="unknown query tag 'bogus'"):
        parse_mix("bfs:1,bogus:2")
    with pytest.raises(ValueError, match="bad weight"):
        parse_mix("bfs:x")
    with pytest.raises(ValueError, match="empty workload mix"):
        parse_mix("bfs:0")


def test_trace_is_deterministic():
    a = synthetic_trace(256, 8, mix="bfs:1,khop:1", seed=5)
    b = synthetic_trace(256, 8, mix="bfs:1,khop:1", seed=5)
    assert [r.query for r in a] == [r.query for r in b]
    assert [r.arrival for r in a] == [r.arrival for r in b]
    assert a[0].arrival == 0 and a[-1].arrival == (7 // 4) * 2


# ---------------------------------------------------------------------------
# Envelope codec + tag registry.
# ---------------------------------------------------------------------------

def test_envelope_wire_roundtrip():
    req = AnalyticsRequest(query=KHopQuery(sources=(3, 17), k=2),
                           id="r1", tenant="acme", arrival=4)
    wire = req.to_wire()
    assert wire["kind"] == "khop" and wire["query"]["sources"] == [3, 17]
    back = AnalyticsRequest.from_wire(wire)
    assert back.query == req.query
    assert (back.id, back.tenant, back.arrival) == ("r1", "acme", 4)


def test_envelope_unknown_tag_is_one_error_path():
    with pytest.raises(ValueError, match="unknown query tag 'nope'"):
        AnalyticsRequest.from_wire(dict(kind="nope", query={}))


def test_envelope_rejects_untyped_query():
    with pytest.raises(TypeError, match="unknown analytics query type"):
        AnalyticsRequest(query=object())


def test_every_query_type_declares_its_own_kind():
    for t in QUERY_TYPES:
        assert QUERY_KINDS[query_kind(t)] is t

    class Tagless:
        pass

    with pytest.raises(TypeError, match="declares no wire tag"):
        query_kind(Tagless)


def test_query_meta_deprecated_dict_access(wg, offline):
    res = run_query(offline, KHopQuery(sources=(0,), k=1))
    assert res.meta.kind == "khop" and res.meta.lanes >= 1
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert res.meta["ndev"] == res.meta.ndev
        assert res.meta.get("kind") == "khop"
        assert "lanes" in res.meta      # membership stays silent
    assert all(issubclass(x.category, DeprecationWarning) for x in w)
    assert len(w) == 2                  # one per __getitem__/.get()


# ---------------------------------------------------------------------------
# Forced multi-device parity: the sharded service streams the same bits.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ndev", [2, 4])
def test_serving_dist_streaming_parity(ndev):
    """Streaming khop/reach answers from an ndev-sharded service must be
    bit-identical to host offline ``run_query`` AND land early."""
    run_in_subprocess(f"""
import numpy as np
from repro.analytics import KHopQuery, ReachQuery, run_query
from repro.core.csr import from_edges
from repro.serving import AnalyticsService

n = 96
g = from_edges(np.arange(n - 1), np.arange(1, n), n)
svc = AnalyticsService(g, slots=4, ndev={ndev}, streaming=True)
kq = KHopQuery(sources=(0, 7), k=2)
rq = ReachQuery(sources=(0,), targets=(5,))
rk = svc.submit(kq)
rr = svc.submit(rq)
svc.run_until_idle()
assert rk.answered_early and rr.answered_early
assert rk.answer.meta.ndev == {ndev}
ref_k = run_query(g, kq)
ref_r = run_query(g, rq)
np.testing.assert_array_equal(rk.answer.result.words, ref_k.words)
np.testing.assert_array_equal(rk.answer.result.counts, ref_k.counts)
np.testing.assert_array_equal(rr.answer.result.hops, ref_r.hops)
assert rr.answer.result.hops[0, 0] == 5
# flush twin on the same mesh: streamed band == flushed band, later
flush = AnalyticsService(g, slots=4, ndev={ndev}, streaming=False)
fk = flush.submit(kq)
flush.run_until_idle()
np.testing.assert_array_equal(rk.answer.result.words,
                              fk.answer.result.words)
assert fk.sojourn - rk.sojourn >= 1
print("ok")
""", devices=ndev)
