"""2-D partitioned MS-BFS: the cross-configuration parity matrix.

The pinning test story of the 2-D rung: depths, parents, layer counts,
edge counters, AND per-layer TD/BU traces must be bit-identical across

  {host pipelined engine, 1-D dist engine, 2-D dist engine}
    x grid {1x1, 1x2, 2x1, 2x2, 4x1, 1x4}     (non-square included)
    x wire format {dense, compressed}
    x LANE_WORD_BITS {32, 64}                  (u64 = x64 subprocess leg)

plus streaming (mid-sweep enqueue), the shared exchange primitives, the
bytes-on-the-wire accounting (star graph: compressed bytes per layer
track the frontier population), and a guard that the 1-D engine still
rides the extracted exchange interface.

Multi-device legs run in subprocesses with forced host devices (conftest
pattern); the u64 legs re-run the SAME code under LANE_WORD_BITS=64 +
JAX_ENABLE_X64=1 via ``run_in_subprocess(env_extra=...)``.
"""
import numpy as np
import pytest

from conftest import run_in_subprocess

U64_ENV = {"LANE_WORD_BITS": "64", "JAX_ENABLE_X64": "1"}
# the u32 leg pins its env too: under the tier1-u64 CI job every
# subprocess inherits LANE_WORD_BITS=64, so the W=32 assertion only
# holds if the default width is forced back explicitly
U32_ENV = {"LANE_WORD_BITS": "32", "JAX_ENABLE_X64": "0"}


# --------------------------------------------------------------------------
# the parity matrix
# --------------------------------------------------------------------------

MATRIX_CODE = """
import sys
sys.path.insert(0, "tests")
import numpy as np
from repro.core import packed
from repro.core.dist_msbfs import dist_msbfs, host_mesh, partition_graph
from repro.core.dist2d import dist2d_msbfs, mesh2d, partition_graph_2d
from repro.core.msbfs import msbfs_pipelined
from test_msbfs_properties import build_case

FIELDS = ("depth", "parent", "num_layers", "edges_traversed",
          "trace_dir", "trace_vf", "trace_ef", "trace_eu")
GRIDS = ((1, 1), (1, 2), (2, 1), (2, 2), (4, 1), (1, 4))

for shape, seed in (("random", 3), ("two_components", 11)):
    g, _ = build_case(60, 150, seed=seed, shape=shape, self_loops=False,
                      dup_edges=False)
    roots = np.array([0, 5, 17, 33, 59], np.int32)
    want = msbfs_pipelined(g, roots, mode="hybrid")
    # 1-D engine row of the matrix
    d1 = dist_msbfs(partition_graph(g, 2), roots, host_mesh(2))
    for f in FIELDS:
        assert np.array_equal(np.asarray(getattr(d1, f)),
                              np.asarray(getattr(want, f))), ("1d", f)
    for (pr, pc) in GRIDS:
        dg = partition_graph_2d(g, pr, pc)
        mesh = mesh2d(pr, pc)
        for compress in (False, True):
            got = dist2d_msbfs(dg, roots, mesh, compress=compress)
            for f in FIELDS:
                assert np.array_equal(
                    np.asarray(getattr(got, f)),
                    np.asarray(getattr(want, f))), (shape, pr, pc,
                                                    compress, f)
print("W=%d MATRIX_OK" % packed.LANE_WORD_BITS)
"""


def test_dist2d_parity_matrix():
    out = run_in_subprocess(MATRIX_CODE, devices=4, timeout=900,
                            env_extra=U32_ENV)
    assert "W=32 MATRIX_OK" in out


def test_dist2d_parity_matrix_u64():
    out = run_in_subprocess(MATRIX_CODE, devices=4, timeout=900,
                            env_extra=U64_ENV)
    assert "W=64 MATRIX_OK" in out


# --------------------------------------------------------------------------
# forced modes + pallas probe through the 2-D exchange
# --------------------------------------------------------------------------

MODES_CODE = """
import sys
sys.path.insert(0, "tests")
import numpy as np
from repro.core import packed
from repro.core.dist2d import dist2d_msbfs, mesh2d, partition_graph_2d
from repro.core.msbfs import msbfs_pipelined
from test_msbfs_properties import build_case

g, _ = build_case(60, 150, seed=7, shape="random", self_loops=False,
                  dup_edges=False)
roots = np.array([0, 5, 17, 33, 59], np.int32)
dg = partition_graph_2d(g, 2, 2)
mesh = mesh2d(2, 2)
for mode in ("topdown", "bottomup"):
    want = msbfs_pipelined(g, roots, mode=mode)
    got = dist2d_msbfs(dg, roots, mesh, mode=mode, compress=True)
    assert np.array_equal(np.asarray(got.depth), np.asarray(want.depth)), mode
    assert np.array_equal(np.asarray(got.parent),
                          np.asarray(want.parent)), mode
# pallas probe (at LANE_WORD_BITS=64: the u64 gather path) x wire format
want = msbfs_pipelined(g, roots, mode="hybrid", probe_impl="pallas")
for compress in (False, True):
    got = dist2d_msbfs(dg, roots, mesh, probe_impl="pallas",
                       compress=compress)
    assert np.array_equal(np.asarray(got.depth), np.asarray(want.depth))
    assert np.array_equal(np.asarray(got.parent), np.asarray(want.parent))
    assert np.array_equal(np.asarray(got.trace_dir),
                          np.asarray(want.trace_dir))
print("W=%d MODES2D_OK" % packed.LANE_WORD_BITS)
"""


def test_dist2d_forced_modes_and_pallas_probe():
    out = run_in_subprocess(MODES_CODE, devices=4, timeout=900,
                            env_extra=U32_ENV)
    assert "W=32 MODES2D_OK" in out


def test_dist2d_forced_modes_and_pallas_probe_u64():
    out = run_in_subprocess(MODES_CODE, devices=4, timeout=900,
                            env_extra=U64_ENV)
    assert "W=64 MODES2D_OK" in out


# --------------------------------------------------------------------------
# streaming enqueue mid-sweep
# --------------------------------------------------------------------------

STREAM_CODE = """
import sys
sys.path.insert(0, "tests")
import numpy as np
from repro.core.dist2d import (dist2d_msbfs_engine_drain,
                               dist2d_msbfs_engine_enqueue,
                               dist2d_msbfs_engine_idle,
                               dist2d_msbfs_engine_init,
                               dist2d_msbfs_engine_result,
                               dist2d_msbfs_engine_step, mesh2d,
                               partition_graph_2d)
from repro.core.msbfs import msbfs_pipelined
from test_msbfs_properties import build_case

g, _ = build_case(60, 150, seed=5, shape="random", self_loops=False,
                  dup_edges=False)
roots = np.array([2, 9, 21, 40, 57], np.int32)
want = msbfs_pipelined(g, roots, mode="hybrid")
dg = partition_graph_2d(g, 2, 2)
mesh = mesh2d(2, 2)
s = dist2d_msbfs_engine_init(dg, mesh, capacity=5, lanes=32)
assert dist2d_msbfs_engine_idle(s)
s = dist2d_msbfs_engine_enqueue(s, roots[:2])
s = dist2d_msbfs_engine_step(dg, s, mesh, compress=True)
assert not dist2d_msbfs_engine_idle(s)
s = dist2d_msbfs_engine_enqueue(s, roots[2:])     # mid-sweep refill
s = dist2d_msbfs_engine_drain(dg, s, mesh, compress=True)
assert dist2d_msbfs_engine_idle(s)
res = dist2d_msbfs_engine_result(dg, s, mesh)
assert np.array_equal(np.asarray(res.depth), np.asarray(want.depth))
assert np.array_equal(np.asarray(res.parent), np.asarray(want.parent))
assert int(s.exch_bytes) > 0 and int(s.exch_bytes) == np.asarray(
    s.exch_log).sum()
print("STREAM2D_OK")
"""


def test_dist2d_streaming_enqueue():
    out = run_in_subprocess(STREAM_CODE, devices=4, timeout=900)
    assert "STREAM2D_OK" in out


# --------------------------------------------------------------------------
# bytes-on-the-wire accounting: compressed layers track the frontier
# --------------------------------------------------------------------------

BYTES_CODE = """
import sys
sys.path.insert(0, "tests")
import numpy as np
from repro.core.dist2d import (dist2d_msbfs_engine_drain,
                               dist2d_msbfs_engine_enqueue,
                               dist2d_msbfs_engine_init, mesh2d,
                               partition_graph_2d)
from test_msbfs_properties import build_case

mesh = mesh2d(2, 2)

def run(g, compress):
    dg = partition_graph_2d(g, 2, 2)
    s = dist2d_msbfs_engine_init(dg, mesh, capacity=1, lanes=32)
    s = dist2d_msbfs_engine_enqueue(s, [0])
    s = dist2d_msbfs_engine_drain(dg, s, mesh, compress=compress)
    return np.asarray(s.exch_log)

# star from the hub: step 0 = sparse expand ({root}) + DENSE fold (the
# 255 discovered leaves), step 1 = dense expand + near-empty fold. The
# switch is per exchange, so each compressed step undercuts dense (which
# ships graph-sized messages regardless of population) but stays in the
# same order of magnitude — only the sparse halves shrink.
g, _ = build_case(256, 0, seed=0, shape="star", self_loops=False,
                  dup_edges=False)
log_c, log_d = run(g, True), run(g, False)
live = log_d > 0
assert log_d[0] == log_d[1] and live.sum() == 2   # dense: population-blind
assert log_c[0] < log_d[0] and log_c[1] < log_d[1], (log_c, log_d)
assert log_c.sum() < log_d.sum()
# step 1's fold is near-empty while step 0's is saturated: the
# difference between the two steps is exactly the dense-vs-sparse fold
assert log_c[1] < log_c[0], (log_c,)

# path: EVERY layer's frontier and discovery is a single vertex, so with
# compression every live layer ships a few index/payload pairs — an
# order of magnitude under the population-blind dense cost
g, _ = build_case(64, 0, seed=0, shape="path", self_loops=False,
                  dup_edges=False)
log_c, log_d = run(g, True), run(g, False)
live = log_d > 0
assert (log_d[live] == log_d[0]).all()
assert (log_c[live] < log_d[0] // 4).all(), (log_c, log_d)
print("BYTES2D_OK")
"""


def test_dist2d_bytes_track_frontier_population():
    out = run_in_subprocess(BYTES_CODE, devices=4, timeout=900)
    assert "BYTES2D_OK" in out


# --------------------------------------------------------------------------
# the shared exchange interface
# --------------------------------------------------------------------------

EXCHANGE_CODE = """
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.core import exchange
from repro.core.compat import shard_map
from repro.distributed.compression import sparse_budget

devs = np.array(jax.devices()[:4]).reshape(2, 2)
mesh = Mesh(devs, ("row", "col"))
rng = np.random.default_rng(0)
own = np.zeros((4, 8, 2), np.uint32)
own[0, 3, 1] = 7                    # grid column 0: sparse slices
own[2, 5, 0] = 9
own[1] = rng.integers(1, 2 ** 31, (8, 2), dtype=np.uint32)   # column 1:
own[3] = rng.integers(1, 2 ** 31, (8, 2), dtype=np.uint32)   # dense

def body(x):
    x = x[0]
    exp_c, b_c = exchange.exchange_expand(x, "row", compress=True)
    exp_d, b_d = exchange.exchange_expand(x, "row", compress=False)
    red_c, rb_c = exchange.exchange_reduce_or(x, "col", compress=True)
    red_d, rb_d = exchange.exchange_reduce_or(x, "col", compress=False)
    ok = (jnp.all(exp_c == exp_d) & jnp.all(red_c == red_d))
    return (ok[None], b_c[None], b_d[None], rb_c[None],
            exp_d[None], red_d[None])

spec = P(("row", "col"))
fn = shard_map(body, mesh=mesh, in_specs=spec,
               out_specs=(spec,) * 4 + (spec, spec), check_vma=False)
ok, b_c, b_d, rb_c, exp_full, red_full = jax.jit(fn)(jnp.asarray(own))
assert bool(np.asarray(ok).all())
# expand for device (i, j): concat over i' of (i', j)'s slice
for i in range(2):
    for j in range(2):
        want = np.concatenate([own[k * 2 + j] for k in range(2)])
        assert np.array_equal(np.asarray(exp_full[i * 2 + j]), want)
        wantr = own[i * 2] | own[i * 2 + 1]
        assert np.array_equal(np.asarray(red_full[i * 2 + j]), wantr)
# byte accounting: 16 words -> budget 4. column 0 ships sparse
# (2 messages x (4 + 1*(4+4)) = 24 B), column 1 over budget -> dense
# (2 x 64 = 128 B); the per-group totals are replicated within the group
b = np.asarray(b_c).reshape(2, 2)
assert (b[:, 0] == 24).all() and (b[:, 1] == 128).all(), b
assert (np.asarray(b_d) == 128).all()
# reduce groups mix one sparse + one dense slice -> pmax forces dense
assert (np.asarray(rb_c) == 128).all()
print("EXCHANGE_OK")
"""


def test_exchange_primitives_on_grid():
    """gather/expand/reduce-OR: compressed == dense content, group-local
    density switch (different grid columns take different cond branches),
    and exact wire-byte totals."""
    out = run_in_subprocess(EXCHANGE_CODE, devices=4, timeout=900)
    assert "EXCHANGE_OK" in out


def test_dist_msbfs_rides_shared_exchange():
    """The 1-D engine's allreduce-OR IS the extracted exchange primitive
    (not a stale copy), and it still matches a host OR-fold exactly."""
    from repro.core import dist_msbfs, exchange
    assert dist_msbfs.allreduce_or is exchange.allreduce_or


ONED_UNCHANGED_CODE = """
import sys
sys.path.insert(0, "tests")
import numpy as np
from repro.core.dist_msbfs import dist_msbfs, host_mesh, partition_graph
from repro.core.msbfs import msbfs_pipelined
from test_msbfs_properties import build_case

g, _ = build_case(48, 120, seed=2, shape="random", self_loops=False,
                  dup_edges=False)
roots = np.array([1, 7, 30], np.int32)
want = msbfs_pipelined(g, roots, mode="hybrid")
got = dist_msbfs(partition_graph(g, 4), roots, host_mesh(4))
for f in ("depth", "parent", "num_layers", "edges_traversed", "trace_dir"):
    assert np.array_equal(np.asarray(getattr(got, f)),
                          np.asarray(getattr(want, f))), f
print("ONED_OK")
"""


def test_dist_msbfs_results_unchanged():
    """1-D engine parity after the exchange extraction (regression guard
    for the refactor — the full 1-D suite lives in test_dist_msbfs.py)."""
    out = run_in_subprocess(ONED_UNCHANGED_CODE, devices=4, timeout=900)
    assert "ONED_OK" in out


# --------------------------------------------------------------------------
# partition + analytics facade (host-side, no subprocess)
# --------------------------------------------------------------------------

def test_partition_graph_2d_shapes_and_edges():
    """Every edge lands in exactly one block, with correct local ids."""
    from repro.core.csr import from_edges
    from repro.core.dist2d import partition_graph_2d
    rng = np.random.default_rng(4)
    src, dst = rng.integers(0, 70, 200), rng.integers(0, 70, 200)
    g = from_edges(src, dst, 70, symmetrize=True, drop_self_loops=True,
                   dedup=False)
    for pr, pc in ((1, 1), (2, 2), (2, 3), (3, 2)):
        dg = partition_graph_2d(g, pr, pc)
        assert dg.n % (pr * pc * 32) == 0
        assert dg.chunk * pr * pc == dg.n
        assert dg.row_ptr.shape == (pr * pc, dg.n_loc_r + 1)
        deg = np.asarray(dg.deg)
        # partial degrees over a row's blocks rebuild its global degree
        gdeg = np.zeros(dg.n, np.int64)
        for i in range(pr):
            for j in range(pc):
                d = i * pc + j
                gdeg[i * dg.n_loc_r:(i + 1) * dg.n_loc_r] += deg[d]
        np.testing.assert_array_equal(gdeg[:g.n], np.asarray(g.deg))
        assert gdeg[g.n:].sum() == 0
        assert int(deg.sum()) == g.m
        # local col ids decode back to the global ids
        col_loc = np.asarray(dg.col_loc)
        col_gid = np.asarray(dg.col_gid)
        for i in range(pr):
            for j in range(pc):
                d = i * pc + j
                k = int(deg[d].sum())
                loc, gid = col_loc[d, :k], col_gid[d, :k]
                assert (gid // dg.chunk % pc == j).all()
                back = (gid // (dg.chunk * pc)) * dg.chunk + gid % dg.chunk
                np.testing.assert_array_equal(loc, back)
                # pads carry the sentinels
                assert (col_loc[d, k:] == dg.n_x).all()
                assert (col_gid[d, k:] == dg.n).all()


def test_partition_graph_2d_validation():
    from repro.core.csr import from_edges
    from repro.core.dist2d import partition_graph_2d
    g = from_edges(np.array([0]), np.array([1]), 4)
    with pytest.raises(ValueError):
        partition_graph_2d(g, 0, 2)


def test_mesh_grid_mismatch_raises():
    from repro.core.csr import from_edges
    from repro.core.dist2d import (dist2d_msbfs_engine_init, mesh2d,
                                   partition_graph_2d)
    g = from_edges(np.array([0, 1]), np.array([1, 2]), 8)
    import jax
    from jax.sharding import Mesh
    dg = partition_graph_2d(g, 1, 1)
    mesh = mesh2d(1, 1)
    dist2d_msbfs_engine_init(dg, mesh, capacity=1)    # matching grid: fine
    with pytest.raises(ValueError, match="repartition"):
        dist2d_msbfs_engine_init(partition_graph_2d(g, 2, 1), mesh,
                                 capacity=1)
    with pytest.raises(ValueError, match="mesh2d"):
        dist2d_msbfs_engine_init(
            dg, Mesh(np.asarray(jax.devices()[:1]), ("data",)), capacity=1)


ENGINE_GRID_CODE = """
import numpy as np
from repro.analytics.engine import LaneEngine
from repro.core.csr import from_edges

rng = np.random.default_rng(1)
src, dst = rng.integers(0, 50, 140), rng.integers(0, 50, 140)
g = from_edges(src, dst, 50, symmetrize=True, drop_self_loops=True,
               dedup=False)
host = LaneEngine(g).sweep([1, 2, 3])
got = LaneEngine(g, grid=(2, 2), compress=True).sweep([1, 2, 3])
assert np.array_equal(np.asarray(got.depth), np.asarray(host.depth))
assert got.depth.shape == host.depth.shape
try:
    LaneEngine(g, grid=(2, 2), mesh=object())
    raise SystemExit("grid+mesh should have raised")
except ValueError:
    pass
try:
    LaneEngine(g, compress=True)
    raise SystemExit("compress without grid should have raised")
except ValueError:
    pass
print("ENGINE_GRID_OK")
"""


def test_lane_engine_grid_path():
    out = run_in_subprocess(ENGINE_GRID_CODE, devices=4, timeout=900)
    assert "ENGINE_GRID_OK" in out
