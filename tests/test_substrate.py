"""Substrate tests: checkpoint atomicity/corruption, optimizer math,
gradient compression, sharding resolver, sampler."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import (compress_tree, decompress_tree,
                                           init_error_state)
from repro.distributed.sharding import resolve_spec
from repro.optim.adamw import (OptConfig, adamw_update, clip_by_global_norm,
                               init_opt_state)
from repro.train.checkpoint import CheckpointManager


# ----------------------------------------------------------------- checkpoint


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    s = _state()
    mgr.save(3, s)
    restored, step = mgr.restore(jax.eval_shape(lambda: s))
    assert step == 3
    for x, y in zip(jax.tree.leaves(s), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_corruption_fallback(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _state(1))
    mgr.save(2, _state(2))
    # corrupt the newest checkpoint file
    newest = sorted(tmp_path.glob("step_*.npz"))[-1]
    newest.write_bytes(b"garbage")
    restored, step = mgr.restore(jax.eval_shape(lambda: _state()))
    assert step == 1, "must fall back to the previous valid checkpoint"
    ref = _state(1)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(ref["a"]))


def test_checkpoint_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for i in range(5):
        mgr.save(i, _state(i))
    assert mgr.latest_step() == 4
    assert len(list(tmp_path.glob("step_*.npz"))) == 2


# ------------------------------------------------------------------ optimizer


def test_adamw_matches_manual_step():
    cfg = OptConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    st = init_opt_state(p, cfg)
    p2, st2 = adamw_update(p, g, st, cfg)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat, vhat = m / 0.1, v / 0.01
    expect = 1.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(float(p2["w"][0]), expect, rtol=1e-6)


def test_adamw_factored_shapes_and_progress():
    cfg = OptConfig(lr=0.01, b1=0.0, factored=True, moment_dtype="bfloat16")
    p = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 8))}
    st = init_opt_state(p, cfg)
    assert "vr" in st["per_param"]["w"] and "v" not in st["per_param"]["w"]
    assert st["per_param"]["w"]["vr"].shape == (16,)
    assert st["per_param"]["w"]["vc"].shape == (8,)
    g = {"w": jnp.ones((16, 8))}
    p2, st2 = adamw_update(p, g, st, cfg)
    assert not np.allclose(np.asarray(p["w"]), np.asarray(p2["w"]))


def test_grad_clip():
    g = {"w": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    assert abs(float(jnp.linalg.norm(clipped["w"])) - 1.0) < 1e-4


# ---------------------------------------------------------------- compression


def test_compression_error_feedback_telescopes():
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    err = init_error_state({"g": g_true})["g"]
    acc_q = jnp.zeros_like(g_true)
    for step in range(50):
        q, e2 = compress_tree({"g": g_true}, {"g": err})
        deq = decompress_tree(q)["g"]
        acc_q = acc_q + deq
        err = e2["g"]
    # mean of dequantised grads converges to the true grad (error feedback)
    np.testing.assert_allclose(np.asarray(acc_q / 50), np.asarray(g_true),
                               atol=2e-2)


def test_quantisation_error_bound():
    x = jnp.asarray(np.linspace(-3, 3, 512, dtype=np.float32))
    q, _ = compress_tree({"x": x}, init_error_state({"x": x}))
    deq = decompress_tree(q)["x"]
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.max(jnp.abs(deq - x))) <= scale * 0.5 + 1e-6


# ------------------------------------------------------------------- sharding


class _FakeMesh:
    def __init__(self, sizes):
        self._sizes = sizes
    @property
    def shape(self):
        return dict(self._sizes)
    @property
    def axis_names(self):
        return tuple(self._sizes)


def test_resolver_divisibility_fallback():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # 40 kv heads don't divide 16 -> dim replicated
    spec = resolve_spec((64, 40, 128), (None, "kv_heads", "kv_seq"), mesh)
    assert spec == jax.sharding.PartitionSpec(None, None, "model") or \
        tuple(spec) == (None, None, "model")
    # vocab divisible -> sharded on model
    spec2 = resolve_spec((128256, 512), ("vocab", "embed"), mesh)
    assert tuple(spec2) == ("model", "data")


def test_resolver_no_double_axis_use():
    mesh = _FakeMesh({"data": 4, "model": 4})
    spec = resolve_spec((16, 16), ("mlp", "heads"), mesh)
    # both want 'model'; second dim must fall back
    assert tuple(spec) in ((("model",), None), ("model",))


def test_resolver_pod_axis():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = resolve_spec((256, 4096), ("batch", None), mesh)
    assert tuple(spec)[0] == ("pod", "data")


# --------------------------------------------------------------------- sampler


def test_sampler_shapes_and_membership():
    from repro.graph.generator import rmat_graph
    from repro.graph.sampler import sample_subgraph
    g = rmat_graph(9, 8, seed=0)
    seeds = jnp.asarray([1, 5, 9, 200], jnp.int32)
    nodes, senders, receivers, mask = sample_subgraph(
        jax.random.PRNGKey(0), g, seeds, fanout=(3, 2))
    assert nodes.shape[0] == 4 + 12 + 24
    assert senders.shape == receivers.shape == mask.shape
    rp = np.asarray(g.row_ptr)
    ci = np.asarray(g.col_idx)
    nd, sd, rd, md = (np.asarray(x) for x in (nodes, senders, receivers, mask))
    for e in range(len(sd)):
        if not md[e]:
            continue
        child = nd[sd[e]]     # sampled neighbour (original id)
        parent = nd[rd[e]]    # requesting node
        assert child in ci[rp[parent]:rp[parent + 1]], (parent, child)


def test_sampler_dedup_count():
    from repro.graph.generator import rmat_graph
    from repro.graph.sampler import dedup_count, sample_subgraph
    g = rmat_graph(8, 8, seed=1)
    seeds = jnp.arange(8, dtype=jnp.int32)
    nodes, *_ = sample_subgraph(jax.random.PRNGKey(1), g, seeds, fanout=(4,))
    uniq = int(dedup_count(nodes, g.n))
    assert 0 < uniq <= nodes.shape[0]
    assert uniq == len(np.unique(np.asarray(nodes)))
