"""Telemetry subsystem tests (``repro.obs``).

Four surfaces:

* the metrics registry — exposition format, idempotent registration,
  label-cardinality bound;
* sweep-log parity — the ``SweepRecorder`` stream reconstructs the
  engines' trace arrays BIT-FOR-BIT and the recorded results equal the
  recorder-off run, on the host engines in-process and on the
  distributed engines (ndev 2/4, grids 1x2/2x2) in forced-device
  subprocesses;
* trace-event export — schema validation, Chrome-JSON round-trip, the
  JSONL flight sink;
* the disabled path — ``recorder=None`` provably never touches
  ``repro.obs.sweeplog`` (a poisoned hook does not fire), and the
  nearest-rank percentile pins (the CI sojourn gates' arithmetic).
"""
import json
from unittest import mock

import numpy as np
import pytest

from conftest import run_in_subprocess
from repro.graph.generator import rmat_graph, rmat_weighted_graph
from repro.obs import (FlightSink, MetricsRegistry, SweepRecorder,
                       Telemetry, metrics_text, service_trace_events,
                       sweep_trace_events, validate_trace_events,
                       write_chrome_trace)

# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_gauge_exposition():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "requests", ("kind", "status"))
    c.labels(kind="bfs", status="QUEUED").inc()
    c.labels(kind="bfs", status="QUEUED").inc(2)
    c.labels(kind="sssp", status="REJECTED").inc()
    reg.gauge("occupancy", "active lanes").set(37.5)
    text = reg.expose()
    assert "# TYPE requests_total counter" in text
    assert '# HELP requests_total requests' in text
    assert 'requests_total{kind="bfs",status="QUEUED"} 3' in text
    assert 'requests_total{kind="sssp",status="REJECTED"} 1' in text
    assert "# TYPE occupancy gauge" in text
    assert "occupancy 37.5" in text
    assert text.endswith("\n")


def test_histogram_exposition_cumulative():
    reg = MetricsRegistry()
    h = reg.histogram("sojourn", "layers", buckets=(1, 5, 10))
    for v in (0.5, 3, 7, 100):
        h.observe(v)
    text = reg.expose()
    assert 'sojourn_bucket{le="1"} 1' in text
    assert 'sojourn_bucket{le="5"} 2' in text
    assert 'sojourn_bucket{le="10"} 3' in text
    assert 'sojourn_bucket{le="+Inf"} 4' in text
    assert "sojourn_sum 110.5" in text
    assert "sojourn_count 4" in text


def test_registry_idempotent_and_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "x", ("k",))
    assert reg.counter("x_total", "x", ("k",)) is a
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("x_total", "x", ("other",))


def test_label_cardinality_bound():
    from repro.obs.metrics import Counter
    c = Counter("leaky_total", labelnames=("id",), max_series=5)
    for i in range(5):
        c.labels(id=str(i)).inc()
    with pytest.raises(ValueError, match="cardinality bound"):
        c.labels(id="one-too-many")
    with pytest.raises(ValueError, match="expected labels"):
        c.labels(wrong="name")
    with pytest.raises(ValueError):
        c.labels(id="0").inc(-1)       # counters are monotone
    with pytest.raises(ValueError, match="labelled"):
        c.inc()                        # labelled counters need .labels()


def test_metrics_text_default_registry():
    assert isinstance(metrics_text(), str)
    reg = MetricsRegistry()
    reg.counter("solo_total").inc(4)
    assert "solo_total 4" in metrics_text(reg)


# ---------------------------------------------------------------------------
# nearest-rank percentile (the CI sojourn gate arithmetic)
# ---------------------------------------------------------------------------


def test_percentile_nearest_rank_pinned():
    from repro.serving.stats import percentile
    xs = list(range(1, 101))           # 1..100
    assert percentile(xs, 50) == 50.0
    assert percentile(xs, 99) == 99.0
    assert percentile(xs, 100) == 100.0
    # the case that distinguishes nearest-rank from linear interpolation:
    # np.percentile([1,2,3,4], 50) == 2.5 — never an observed sample
    assert percentile([1, 2, 3, 4], 50) == 2.0
    assert percentile([1, 2, 3, 4], 99) == 4.0
    assert percentile([7], 99) == 7.0
    assert percentile([], 50) == 0.0
    # always an actual sample
    xs = [0.3, 11.0, 2.5, 8.125]
    for p in (1, 25, 50, 75, 99):
        assert percentile(xs, p) in xs


# ---------------------------------------------------------------------------
# host sweep-log parity
# ---------------------------------------------------------------------------


def test_host_msbfs_recorder_parity():
    from repro.core.hybrid import MAX_TRACE
    from repro.core.msbfs import msbfs_pipelined
    g = rmat_graph(8, edgefactor=8, seed=11)
    roots = np.arange(24, dtype=np.int32) % g.n
    base = msbfs_pipelined(g, roots, lanes=8)
    rec = SweepRecorder(engine="msbfs")
    got = msbfs_pipelined(g, roots, lanes=8, recorder=rec)
    for f in ("parent", "depth", "num_layers", "edges_traversed",
              "trace_dir", "trace_vf", "trace_ef", "trace_eu"):
        assert np.array_equal(np.asarray(getattr(base, f)),
                              np.asarray(getattr(got, f))), f
    # the recorder's layer/mode stream rebuilds the engine traces exactly
    tr = rec.reconstruct_traces(MAX_TRACE, roots.size)
    for f in ("trace_dir", "trace_vf", "trace_ef", "trace_eu"):
        assert np.array_equal(tr[f], np.asarray(getattr(base, f))), f
    assert rec.num_layers == len(rec.records) > 0
    assert set(rec.modes()) <= {"td", "bu", "mixed", "idle"}
    assert any(r.active_lanes > 0 for r in rec.records)
    for r in rec.records:
        assert r.kind == "bfs" and r.engine == "msbfs"
        assert r.active_lanes == len(r.slots)
        assert 0.0 <= r.frontier_density <= 1.0
        assert r.exch_bytes == 0 and r.exch_format == "none"
        assert r.edges_relaxed >= 0 and r.words_touched >= 0


def test_host_sssp_recorder_parity():
    from repro.traversal.sssp import MAX_SSSP_TRACE, sssp_pipelined
    wg = rmat_weighted_graph(8, edgefactor=8, seed=12)
    src = np.arange(10, dtype=np.int32) % wg.csr.n
    base = sssp_pipelined(wg, src, lanes=4)
    rec = SweepRecorder(engine="sssp")
    got = sssp_pipelined(wg, src, lanes=4, recorder=rec)
    for f in ("sources", "dist", "steps", "truncated", "trace_bucket",
              "trace_phase"):
        assert np.array_equal(np.asarray(getattr(base, f)),
                              np.asarray(getattr(got, f))), f
    tr = rec.reconstruct_traces(MAX_SSSP_TRACE, src.size)
    assert np.array_equal(tr["trace_bucket"], np.asarray(base.trace_bucket))
    assert np.array_equal(tr["trace_phase"], np.asarray(base.trace_phase))
    assert set(rec.modes()) <= {"light", "heavy", "mixed", "idle"}


def test_recorder_disabled_never_touches_obs():
    """The zero-cost guarantee: with ``recorder=None`` the drivers and
    the service must never call into ``repro.obs.sweeplog`` — poisoning
    the snapshot hook proves it."""
    from repro.core.msbfs import msbfs_pipelined
    g = rmat_graph(7, edgefactor=8, seed=13)
    roots = np.arange(6, dtype=np.int32)
    boom = mock.patch("repro.obs.sweeplog.snapshot_state",
                      side_effect=AssertionError("obs touched"))
    with boom:
        msbfs_pipelined(g, roots, lanes=8)          # recorder=None: fine
        from repro.serving import AnalyticsService, ServiceConfig
        from repro.serving.trace import synthetic_trace
        wg = rmat_weighted_graph(7, 8, 13)
        svc = AnalyticsService(wg, ServiceConfig(lanes=8, slots=16))
        svc.replay(synthetic_trace(wg.n, 4, mix="bfs", seed=0))
    # ...and the poison is real: a live recorder DOES hit the hook
    with boom, pytest.raises(AssertionError, match="obs touched"):
        msbfs_pipelined(g, roots, lanes=8,
                        recorder=SweepRecorder(engine="msbfs"))


# ---------------------------------------------------------------------------
# distributed sweep-log parity (forced-device subprocesses)
# ---------------------------------------------------------------------------

_DIST_CODE = """
import numpy as np
from repro.graph.generator import rmat_graph
from repro.core.hybrid import MAX_TRACE
from repro.core.msbfs import msbfs_pipelined
from repro.obs import SweepRecorder

g = rmat_graph(8, edgefactor=8, seed=21)
roots = np.arange(16, dtype=np.int32) %% g.n
host = msbfs_pipelined(g, roots, lanes=8)

%(engine_setup)s

rec = SweepRecorder(engine=%(engine_name)r)
res = %(engine_call)s
assert np.array_equal(np.asarray(host.depth), np.asarray(res.depth))
tr = rec.reconstruct_traces(MAX_TRACE, roots.size)
for f in ("trace_dir", "trace_vf", "trace_ef", "trace_eu"):
    assert np.array_equal(tr[f], np.asarray(getattr(res, f))), f
    assert np.array_equal(tr[f], np.asarray(getattr(host, f))), f
assert rec.num_layers == len(rec.records) > 0
assert set(rec.modes()) <= {"td", "bu", "mixed", "idle"}
%(extra)s
print("OBS_DIST_OK", rec.num_layers)
"""


@pytest.mark.parametrize("ndev", [2, 4])
def test_dist_msbfs_recorder_parity(ndev):
    setup = f"""
from repro.core.dist_msbfs import dist_msbfs, host_mesh, partition_graph
mesh = host_mesh({ndev})
dg = partition_graph(g, {ndev})
"""
    code = _DIST_CODE % dict(
        engine_setup=setup, engine_name="dist_msbfs",
        engine_call="dist_msbfs(dg, roots, mesh, lanes=8, recorder=rec)",
        extra="assert all(r.exch_bytes == 0 for r in rec.records)")
    assert "OBS_DIST_OK" in run_in_subprocess(code, devices=ndev)


@pytest.mark.parametrize("grid", [(1, 2), (2, 2)])
def test_dist2d_recorder_parity(grid):
    pr, pc = grid
    setup = f"""
from repro.core.dist2d import dist2d_msbfs, mesh2d, partition_graph_2d
mesh = mesh2d({pr}, {pc})
dg2 = partition_graph_2d(g, {pr}, {pc})
"""
    extra = """
# per-layer exchange deltas must sum to the state's total byte meter
from repro.core import dist2d as d2
st = d2.dist2d_msbfs_engine_init(dg2, mesh, capacity=roots.size, lanes=8)
st = d2.dist2d_msbfs_engine_enqueue(st, roots)
st = d2.dist2d_msbfs_engine_drain(dg2, st, mesh, compress=True)
assert int(rec.total("exch_bytes")) == int(np.asarray(st.exch_bytes))
assert {r.exch_format for r in rec.records} == {"compressed"}
"""
    code = _DIST_CODE % dict(
        engine_setup=setup, engine_name="dist2d",
        engine_call="dist2d_msbfs(dg2, roots, mesh, lanes=8, "
                    "compress=True, recorder=rec)",
        extra=extra)
    assert "OBS_DIST_OK" in run_in_subprocess(code, devices=pr * pc)


@pytest.mark.parametrize("ndev", [2])
def test_dist_sssp_recorder_parity(ndev):
    code = f"""
import numpy as np
from repro.graph.generator import rmat_weighted_graph
from repro.traversal.sssp import MAX_SSSP_TRACE, sssp_pipelined
from repro.core.dist_sssp import (dist_sssp, partition_weighted_graph)
from repro.core.dist_msbfs import host_mesh
from repro.obs import SweepRecorder

wg = rmat_weighted_graph(8, edgefactor=8, seed=22)
src = np.arange(8, dtype=np.int32) % wg.csr.n
host = sssp_pipelined(wg, src, lanes=4)
mesh = host_mesh({ndev})
dwg = partition_weighted_graph(wg, {ndev})
rec = SweepRecorder(engine="dist_sssp")
res = dist_sssp(dwg, src, mesh, lanes=4, compress=True, recorder=rec)
assert np.array_equal(np.asarray(host.dist), np.asarray(res.dist))
tr = rec.reconstruct_traces(MAX_SSSP_TRACE, src.size)
assert np.array_equal(tr["trace_bucket"], np.asarray(res.trace_bucket))
assert np.array_equal(tr["trace_phase"], np.asarray(res.trace_phase))
assert np.array_equal(tr["trace_phase"], np.asarray(host.trace_phase))
assert int(rec.total("exch_bytes")) > 0
print("OBS_DIST_SSSP_OK", rec.num_layers)
"""
    assert "OBS_DIST_SSSP_OK" in run_in_subprocess(code, devices=ndev)


# ---------------------------------------------------------------------------
# trace-event export
# ---------------------------------------------------------------------------


def _recorded_sweep():
    from repro.core.msbfs import msbfs_pipelined
    g = rmat_graph(7, edgefactor=8, seed=31)
    rec = SweepRecorder(engine="msbfs")
    msbfs_pipelined(g, np.arange(8, dtype=np.int32), lanes=8, recorder=rec)
    return rec


def test_sweep_trace_events_schema(tmp_path):
    rec = _recorded_sweep()
    events = validate_trace_events(sweep_trace_events(rec))
    spans = [e for e in events if e["ph"] == "X"]
    assert len(spans) == rec.num_layers
    for e in spans:
        assert e["dur"] > 0 and e["ts"] >= 0
        assert e["args"]["mode"] in ("td", "bu", "mixed", "idle")
    # metadata names the process for Perfetto's track grouping
    metas = [e for e in events if e["ph"] == "M"]
    assert any(e["args"]["name"] == "sweep:msbfs" for e in metas)
    path = write_chrome_trace(str(tmp_path / "sweep.json"), events)
    with open(path) as f:
        doc = json.load(f)
    assert doc["traceEvents"] == events


def test_service_trace_events(tmp_path):
    from repro.serving import AnalyticsService, ServiceConfig
    from repro.serving.trace import synthetic_trace
    tel = Telemetry()
    wg = rmat_weighted_graph(7, 8, 32)
    svc = AnalyticsService(wg, ServiceConfig(lanes=8, slots=32,
                                             telemetry=tel))
    svc.replay(synthetic_trace(wg.n, 8, mix="bfs:2,khop:1", seed=1))
    events = validate_trace_events(svc.trace_events())
    names = " ".join(e["name"] for e in events)
    assert "QUEUED" in names and "RUNNING" in names
    write_chrome_trace(str(tmp_path / "svc.json"), events)
    # telemetry collected the pool's per-layer stream + service metrics
    assert tel.sweeps and tel.sweeps[0].num_layers > 0
    text = svc.metrics_text()
    assert "service_requests_total" in text
    assert "service_answers_total" in text
    assert "service_layers_total" in text
    assert "obs_sweep_layers_total" in text


def test_validate_trace_events_rejects():
    with pytest.raises(ValueError, match="must be a list"):
        validate_trace_events({"not": "a list"})
    with pytest.raises(ValueError, match="unknown phase"):
        validate_trace_events([dict(name="x", ph="Z", pid=1, tid=1)])
    with pytest.raises(ValueError, match="missing 'dur'"):
        validate_trace_events([dict(name="x", ph="X", pid=1, tid=1, ts=0)])
    with pytest.raises(ValueError, match="pid/tid must be integers"):
        validate_trace_events([dict(name="x", ph="i", pid="p", tid=1,
                                    ts=0)])


def test_flight_sink_jsonl(tmp_path):
    path = str(tmp_path / "flight.jsonl")
    rec = SweepRecorder(engine="msbfs", sink=FlightSink(path))
    from repro.core.msbfs import msbfs_pipelined
    g = rmat_graph(7, edgefactor=8, seed=33)
    msbfs_pipelined(g, np.arange(6, dtype=np.int32), lanes=8, recorder=rec)
    rec.sink.close()
    with open(path) as f:
        lines = [json.loads(ln) for ln in f]
    assert len(lines) == rec.num_layers
    for ln, r in zip(lines, rec.records):
        assert ln["layer"] == r.layer and ln["mode"] == r.mode
        assert ln["engine"] == "msbfs" and ln["kind"] == "bfs"


def test_telemetry_bundle_off_returns_none():
    tel = Telemetry(record_sweeps=False)
    assert tel.recorder("msbfs") is None
    assert tel.sweeps == [] and tel.last_sweep() is None
    tel.registry.counter("still_works_total").inc()
    assert "still_works_total 1" in tel.metrics_text()


def test_telemetry_sweep_eviction_is_counted():
    """No silent caps: every sweep evicted by the ``max_sweeps`` bound
    bumps ``obs_sweeps_dropped_total`` on the bundle's registry."""
    tel = Telemetry(max_sweeps=3)
    recs = [tel.recorder("msbfs") for _ in range(3)]
    assert tel.sweeps == recs                   # under the bound: no drop
    assert "obs_sweeps_dropped_total" not in tel.metrics_text()
    tel.recorder("msbfs")
    tel.recorder("msbfs")
    assert len(tel.sweeps) == 3                 # bound held...
    kept = [id(r) for r in tel.sweeps]          # ...oldest two evicted
    # identity, not ==: empty recorders are value-equal dataclasses
    assert id(recs[0]) not in kept and id(recs[1]) not in kept
    assert id(recs[2]) in kept
    assert "obs_sweeps_dropped_total 2" in tel.metrics_text()
