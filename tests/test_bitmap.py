"""Packed uint32 bitmap ops. Hypothesis property tests are
importorskip-guarded; deterministic fallback sweeps always run."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitmap

DET_CASES = [(1, 0), (31, 1), (32, 2), (33, 3), (100, 4), (300, 5)]


def _check_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    mask = jnp.asarray(rng.random(n) < 0.5)
    words = bitmap.pack(mask)
    assert words.dtype == jnp.uint32
    assert words.shape[0] == bitmap.num_words(n)
    back = bitmap.unpack(words, n)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(mask))


def _check_test_matches_mask(n, seed):
    rng = np.random.default_rng(seed)
    mask = jnp.asarray(rng.random(n) < 0.3)
    words = bitmap.pack(mask)
    idx = jnp.asarray(rng.integers(0, n, 64))
    got = bitmap.test(words, idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(mask)[idx])


def _check_popcount(n, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random(n) < 0.5
    words = bitmap.pack(jnp.asarray(mask))
    assert int(bitmap.popcount_words(words)) == int(mask.sum())


@pytest.mark.parametrize("n,seed", DET_CASES)
def test_deterministic_sweep(n, seed):
    """Fixed fallback case set — always runs, hypothesis or not."""
    _check_pack_unpack_roundtrip(n, seed)
    _check_test_matches_mask(n, seed)
    _check_popcount(n, seed)


def test_property_bitmap_ops():
    """Hypothesis sweep — skipped when hypothesis is absent."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 300), st.integers(0, 2 ** 31 - 1))
    def inner(n, seed):
        _check_pack_unpack_roundtrip(n, seed)
        _check_test_matches_mask(n, seed)
        _check_popcount(n, seed)

    inner()


def test_out_of_range_is_false():
    words = bitmap.pack(jnp.ones(10, bool))
    assert not bool(bitmap.test(words, jnp.asarray([320]))[0])


def test_set_bits_scatter_or():
    n = 100
    words = bitmap.pack(jnp.zeros(n, bool))
    idx = jnp.asarray([0, 31, 32, 63, 64, 99, 99])
    words = bitmap.set_bits(words, idx)
    mask = np.asarray(bitmap.unpack(words, n))
    assert set(np.flatnonzero(mask)) == {0, 31, 32, 63, 64, 99}
