import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bitmap


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 300), st.integers(0, 2 ** 31 - 1))
def test_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    mask = jnp.asarray(rng.random(n) < 0.5)
    words = bitmap.pack(mask)
    assert words.dtype == jnp.uint32
    assert words.shape[0] == bitmap.num_words(n)
    back = bitmap.unpack(words, n)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(mask))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 300), st.integers(0, 2 ** 31 - 1))
def test_test_matches_mask(n, seed):
    rng = np.random.default_rng(seed)
    mask = jnp.asarray(rng.random(n) < 0.3)
    words = bitmap.pack(mask)
    idx = jnp.asarray(rng.integers(0, n, 64))
    got = bitmap.test(words, idx)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(mask)[idx])


def test_out_of_range_is_false():
    words = bitmap.pack(jnp.ones(10, bool))
    assert not bool(bitmap.test(words, jnp.asarray([320]))[0])


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 400), st.integers(0, 2 ** 31 - 1))
def test_popcount(n, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random(n) < 0.5
    words = bitmap.pack(jnp.asarray(mask))
    assert int(bitmap.popcount_words(words)) == int(mask.sum())


def test_set_bits_scatter_or():
    n = 100
    words = bitmap.pack(jnp.zeros(n, bool))
    idx = jnp.asarray([0, 31, 32, 63, 64, 99, 99])
    words = bitmap.set_bits(words, idx)
    mask = np.asarray(bitmap.unpack(words, n))
    assert set(np.flatnonzero(mask)) == {0, 31, 32, 63, 64, 99}
