"""BFS correctness: every mode == numpy oracle exactly (deterministic
min-parent rule), Graph500 validator, heuristic trace shape.

The hypothesis property test is importorskip-guarded (the container may
not ship hypothesis); a deterministic fallback case set always runs.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.csr import to_numpy_adj
from repro.core.hybrid import bfs
from repro.core.ref import bfs_queue, bfs_reference
from repro.graph.generator import (rmat_graph, sample_roots,
                                   uniform_random_graph)
from repro.graph.validate import ValidationError, validate_bfs_tree

MODES = ["hybrid", "topdown", "bottomup_simd", "bottomup_nosimd",
         "hybrid_nosimd"]


@pytest.fixture(scope="module")
def g_rmat():
    return rmat_graph(10, 16, seed=0)


@pytest.mark.parametrize("mode", MODES)
def test_modes_match_oracle_rmat(g_rmat, mode):
    rp, ci = to_numpy_adj(g_rmat)
    for root in sample_roots(g_rmat, 3, seed=1):
        out = bfs(g_rmat, int(root), mode)
        pref, _ = bfs_reference(rp, ci, int(root))
        np.testing.assert_array_equal(np.asarray(out.parent), pref)
        np.testing.assert_array_equal(np.asarray(out.depth),
                                      bfs_queue(rp, ci, int(root)))
        validate_bfs_tree(rp, ci, np.asarray(out.parent), int(root))


def _check_random_graph(n, m, seed):
    g = uniform_random_graph(n, m, seed=seed)
    rp, ci = to_numpy_adj(g)
    deg = np.asarray(g.deg)
    roots = np.flatnonzero(deg > 0)
    if len(roots) == 0:
        return
    root = int(roots[seed % len(roots)])
    pref, dref = bfs_reference(rp, ci, root)
    for mode in ("hybrid", "bottomup_simd"):
        out = bfs(g, root, mode)
        np.testing.assert_array_equal(np.asarray(out.parent), pref)
        np.testing.assert_array_equal(np.asarray(out.depth), dref)


def test_property_random_graphs():
    """Hypothesis sweep over G(n, m) graphs — skipped without hypothesis
    (the deterministic fallback below still pins the same invariant)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=10, deadline=None)
    @given(st.integers(10, 400), st.integers(10, 1200),
           st.integers(0, 10 ** 6))
    def inner(n, m, seed):
        _check_random_graph(n, m, seed)

    inner()


@pytest.mark.parametrize("n,m,seed", [
    (10, 10, 0), (37, 80, 1), (128, 512, 2), (400, 1200, 3), (61, 15, 4),
])
def test_deterministic_random_graphs(n, m, seed):
    """Fixed fallback case set for the property above — always runs."""
    _check_random_graph(n, m, seed)


def test_max_pos_invariance(g_rmat):
    """Parents must be identical for any MAX_POS (fallback covers the rest)."""
    rp, ci = to_numpy_adj(g_rmat)
    root = int(sample_roots(g_rmat, 1, seed=3)[0])
    pref, _ = bfs_reference(rp, ci, root)
    for max_pos in (1, 4, 8, 32):
        out = bfs(g_rmat, root, "bottomup_simd", 14.0, 24.0, max_pos)
        np.testing.assert_array_equal(np.asarray(out.parent), pref)


def test_hybrid_trace_pattern(g_rmat):
    """Paper Table 2: TD on the first layer, BU in the middle layers."""
    root = int(sample_roots(g_rmat, 1, seed=1)[0])
    out = bfs(g_rmat, root, "hybrid")
    dirs = np.asarray(out.trace_dir)[:int(out.num_layers)]
    assert dirs[0] == 0, "layer 1 must be top-down"
    assert (dirs == 1).any(), "middle layers must switch to bottom-up"


def test_counters_monotonic(g_rmat):
    root = int(sample_roots(g_rmat, 1, seed=1)[0])
    out = bfs(g_rmat, root, "hybrid")
    n_layers = int(out.num_layers)
    eu = np.asarray(out.trace_eu)[:n_layers]
    assert (np.diff(eu) <= 0).all(), "unexplored edges must shrink"


def test_pallas_probe_end_to_end(g_rmat):
    rp, ci = to_numpy_adj(g_rmat)
    root = int(sample_roots(g_rmat, 1, seed=2)[0])
    out = bfs(g_rmat, root, "hybrid", 14.0, 24.0, 8, "pallas")
    pref, _ = bfs_reference(rp, ci, root)
    np.testing.assert_array_equal(np.asarray(out.parent), pref)


def test_validator_catches_bad_trees(g_rmat):
    rp, ci = to_numpy_adj(g_rmat)
    root = int(sample_roots(g_rmat, 1, seed=1)[0])
    out = bfs(g_rmat, root, "hybrid")
    parent = np.asarray(out.parent).copy()
    # corrupt: point a reached vertex at a non-adjacent vertex
    reached = np.flatnonzero((parent >= 0) & (np.arange(len(parent)) != root))
    v = int(reached[0])
    adj = set(ci[rp[v]:rp[v + 1]])
    bad = next(u for u in range(g_rmat.n) if u not in adj and u != v)
    parent[v] = bad
    with pytest.raises(ValidationError):
        validate_bfs_tree(rp, ci, parent, root)
    # corrupt: create a 2-cycle
    parent2 = np.asarray(out.parent).copy()
    a = int(reached[1])
    b = int(parent2[a])
    if b != root:
        parent2[b] = a
        with pytest.raises(ValidationError):
            validate_bfs_tree(rp, ci, parent2, root)


def test_result_dtypes_and_counter_headroom(g_rmat):
    """BFSResult counters are int32 as documented; int32 has headroom for
    the documented scale-20 protocol and ``from_edges`` rejects graphs
    whose edge count would overflow the counters."""
    root = int(sample_roots(g_rmat, 1, seed=1)[0])
    out = bfs(g_rmat, root, "hybrid")
    for name in ("parent", "depth", "num_layers", "edges_traversed",
                 "trace_dir", "trace_vf", "trace_ef", "trace_eu"):
        assert getattr(out, name).dtype == jnp.int32, name
    # edges_traversed and every trace counter are bounded by m (directed
    # edges). Scale 20 / edgefactor 16 symmetrised: m <= 2 * 16 * 2**20.
    assert 2 * 16 * 2 ** 20 < 2 ** 31
    # a component can never traverse more than m edge lanes
    assert int(out.edges_traversed) <= g_rmat.m
    # the guard refuses int32-overflowing edge counts up front (zero-copy
    # broadcast views — the guard must fire before any materialisation)
    from repro.core.csr import from_edges
    big = np.broadcast_to(np.int8(0), (2 ** 31 + 8,))
    with pytest.raises(ValueError, match="overflow"):
        from_edges(big, big, 4, symmetrize=False, drop_self_loops=False)


def test_ell_topdown_matches_oracle(g_rmat):
    """Beyond-paper ELL top-down (bounded slabs + residue) is exact."""
    rp, ci = to_numpy_adj(g_rmat)
    for root in sample_roots(g_rmat, 2, seed=7):
        for mode in ("hybrid", "topdown"):
            out = bfs(g_rmat, int(root), mode, 14.0, 24.0, 8, "xla", True,
                      "ell")
            pref, _ = bfs_reference(rp, ci, int(root))
            np.testing.assert_array_equal(np.asarray(out.parent), pref)
