"""End-to-end behaviour tests for the paper's system (Graph500 harness,
hybrid switching, MAX_POS claim, trainer fault tolerance, elastic re-mesh)."""
import numpy as np

from conftest import run_in_subprocess


def test_graph500_harness_end_to_end():
    from repro.graph.graph500 import run_graph500
    res = run_graph500(9, 8, mode="hybrid", num_roots=4, seed=0,
                       validate=True)
    s = res.summary()
    assert s["nroots"] == 4
    assert s["harmonic_mean_teps"] > 0
    assert s["min_teps"] > 0


def test_hybrid_switch_uses_both_directions():
    """Paper Table 2: the hybrid must actually use both TD and BU layers on
    a Graph500 graph (otherwise it degenerates to one of the baselines)."""
    from repro.core.hybrid import bfs
    from repro.graph.generator import rmat_graph, sample_roots
    g = rmat_graph(11, 16, seed=2)
    root = int(sample_roots(g, 1, seed=5)[0])
    out = bfs(g, root, "hybrid")
    dirs = np.asarray(out.trace_dir)[:int(out.num_layers)]
    assert (dirs == 0).any() and (dirs == 1).any()


def test_max_pos_retires_most_vertices():
    """Paper §5.2/Table 3: at the big middle layer, MAX_POS=8 probes retire
    the overwhelming majority of the vertices that find parents (that is the
    premise of the vectorised bottom-up)."""
    import jax.numpy as jnp
    from repro.core.bottomup import bottomup_probe_stats
    from repro.core.hybrid import bfs
    from repro.graph.generator import rmat_graph, sample_roots
    g = rmat_graph(11, 16, seed=0)
    root = int(sample_roots(g, 1, seed=1)[0])
    out = bfs(g, root, "hybrid")
    depth = np.asarray(out.depth)
    # reconstruct the state entering the biggest bottom-up layer (depth==2)
    visited = jnp.asarray((depth >= 0) & (depth < 2))
    frontier = jnp.asarray(depth == 1)
    stats = bottomup_probe_stats(g, frontier, visited, max_pos=8)
    retired = int(stats["retired"])
    found_this_layer = int((depth == 2).sum())
    assert retired >= 0.95 * found_this_layer, (retired, found_this_layer)


def test_trainer_kill_and_resume_determinism(tmp_path):
    """Fault tolerance: run 6 steps; separately run 3 steps, 'die', resume,
    3 more — final losses must match exactly (data stream is step-keyed)."""
    from repro.configs.reduced import reduce_arch
    from repro.train.trainer import Trainer, TrainerConfig

    arch = reduce_arch("gcn-cora")
    a = Trainer(arch, "full_graph_sm",
                cfg=TrainerConfig(steps=6, ckpt_every=100, log_every=1,
                                  ckpt_dir=str(tmp_path / "a")))
    log_a = a.run()

    b1 = Trainer(arch, "full_graph_sm",
                 cfg=TrainerConfig(steps=3, ckpt_every=3, log_every=1,
                                   ckpt_dir=str(tmp_path / "b")))
    b1.run()
    del b1   # "node failure"
    b2 = Trainer(arch, "full_graph_sm",
                 cfg=TrainerConfig(steps=6, ckpt_every=100, log_every=1,
                                   ckpt_dir=str(tmp_path / "b")))
    log_b = b2.run()
    assert abs(log_a[-1]["loss"] - log_b[-1]["loss"]) < 1e-5


ELASTIC_CODE = """
import jax, numpy as np
from repro.configs.reduced import reduce_arch
from repro.train.trainer import Trainer, TrainerConfig

arch = reduce_arch('gcn-cora')
mesh1 = jax.make_mesh((4, 2), ('data', 'model'))
tr = Trainer(arch, 'full_graph_sm', mesh=mesh1,
             cfg=TrainerConfig(steps=4, log_every=1))
tr.run(2)
# simulate losing a host: shrink to 4 devices
mesh2 = jax.make_mesh((2, 2), ('data', 'model'))
tr.remesh(mesh2)
m = tr.run_step()
print('ELASTIC_LOSS', float(np.asarray(m['loss'])))

# reference: same 3 steps on the small mesh from scratch
tr2 = Trainer(arch, 'full_graph_sm', mesh=mesh2,
              cfg=TrainerConfig(steps=4, log_every=1))
tr2.run(2)
m2 = tr2.run_step()
print('REF_LOSS', float(np.asarray(m2['loss'])))
"""


def test_elastic_remesh_preserves_training():
    out = run_in_subprocess(ELASTIC_CODE, devices=8)
    vals = {}
    for line in out.splitlines():
        if line.startswith(("ELASTIC_LOSS", "REF_LOSS")):
            k, v = line.split()
            vals[k] = float(v)
    assert abs(vals["ELASTIC_LOSS"] - vals["REF_LOSS"]) < 1e-4, vals


def test_straggler_rebalance_batch_permutation():
    """Straggler mitigation = permuting host->slice assignment; the global
    batch must be invariant under the permutation."""
    from repro.configs.reduced import reduce_arch
    from repro.data.pipeline import make_batch
    arch = reduce_arch("dien")
    shape = arch.shape("train_batch")
    parts = [make_batch(arch, shape, 7, seed=0, host_id=h, n_hosts=4)
             for h in range(4)]
    full = {k: np.concatenate([np.asarray(p[k]) for p in parts])
            for k in parts[0]}
    perm = [2, 0, 3, 1]
    full_p = {k: np.concatenate([np.asarray(parts[i][k]) for i in perm])
              for k in parts[0]}
    assert sorted(full["target_item"].tolist()) == \
        sorted(full_p["target_item"].tolist())
