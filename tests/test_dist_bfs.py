"""Distributed BFS on 8 fake host devices (subprocess sets XLA_FLAGS)."""
from conftest import run_in_subprocess

CODE = """
import numpy as np, jax
from repro.graph.generator import rmat_graph, sample_roots, uniform_random_graph
from repro.core.dist_bfs import partition_graph, dist_bfs
from repro.core.ref import bfs_reference
from repro.core.csr import to_numpy_adj

meshes = [jax.make_mesh((4, 2), ('data', 'model')),
          jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))]
for g in [rmat_graph(9, 8, seed=0), uniform_random_graph(333, 2000, seed=4)]:
    rp, ci = to_numpy_adj(g)
    dg = partition_graph(g, 8)
    roots = sample_roots(g, 2, seed=1)
    for mesh in meshes:
        for mode in ['hybrid', 'topdown', 'bottomup']:
            for r in roots:
                res = dist_bfs(dg, int(r), mesh, mode)
                pref, dref = bfs_reference(rp, ci, int(r))
                assert (np.asarray(res.parent) == pref).all(), (mode, int(r))
                assert (np.asarray(res.depth) == dref).all(), (mode, int(r))
                assert int(res.num_layers) >= int(dref.max())
print('DIST_OK')
"""


def test_dist_bfs_matches_oracle():
    out = run_in_subprocess(CODE, devices=8)
    assert "DIST_OK" in out


PALLAS_CODE = """
import numpy as np, jax
from repro.graph.generator import rmat_graph, sample_roots
from repro.core.dist_bfs import partition_graph, dist_bfs
from repro.core.ref import bfs_reference
from repro.core.csr import to_numpy_adj
g = rmat_graph(9, 8, seed=3)
rp, ci = to_numpy_adj(g)
mesh = jax.make_mesh((4, 2), ('data', 'model'))
dg = partition_graph(g, 8)
r = int(sample_roots(g, 1, seed=1)[0])
res = dist_bfs(dg, r, mesh, 'hybrid', probe_impl='pallas')
pref, dref = bfs_reference(rp, ci, r)
assert (np.asarray(res.parent) == pref).all()
assert (np.asarray(res.depth) == dref).all()
print('PALLAS_DIST_OK')
"""


def test_dist_bfs_pallas_probe():
    out = run_in_subprocess(PALLAS_CODE, devices=8)
    assert "PALLAS_DIST_OK" in out


OWNER_AGG_CODE = """
import jax, jax.numpy as jnp, numpy as np
from repro.core.compat import set_mesh
from repro.distributed.aggregate import owner_gather_scatter

n, e, d = 64, 256, 8   # divisible by 8 devices
ks = jax.random.split(jax.random.PRNGKey(0), 4)
feats = jax.random.normal(ks[0], (n, d))
snd = jax.random.randint(ks[1], (e,), 0, n, jnp.int32)
rcv = jax.random.randint(ks[2], (e,), 0, n, jnp.int32)
w = jax.random.normal(ks[3], (e,))
fn = lambda hj, ww: hj * ww[:, None]

plain = owner_gather_scatter(feats, snd, rcv, w, fn, n)   # no mesh
mesh = jax.make_mesh((2, 2, 2), ('pod', 'data', 'model'))
with set_mesh(mesh):
    sharded = jax.jit(lambda f: owner_gather_scatter(f, snd, rcv, w, fn, n))(feats)
np.testing.assert_allclose(np.asarray(plain), np.asarray(sharded),
                           rtol=1e-5, atol=1e-5)
# grads flow through the shard_map path
with set_mesh(mesh):
    gr = jax.jit(jax.grad(lambda f: owner_gather_scatter(
        f, snd, rcv, w, fn, n).sum()))(feats)
assert np.isfinite(np.asarray(gr)).all()
print('OWNER_AGG_OK')
"""


def test_owner_gather_scatter_equivalence_and_grads():
    out = run_in_subprocess(OWNER_AGG_CODE, devices=8)
    assert "OWNER_AGG_OK" in out
