"""Sweep-doctor tests (``repro.obs.doctor``).

Four surfaces:

* oracle parity — ``replay_switch`` is bit-identical to the jitted
  ``core.hybrid.switch_direction`` over random and boundary counters
  (the float32 casts matter: that is the whole point of the oracle);
* the acceptance pins — a seeded mis-switched layer on a synthetic
  ``LayerRecord`` trace is flagged (layer, slot, wasted edges), and a
  healthy scale-10 recorded sweep audits to ZERO anomalies;
* the other anomaly families — exchange regression against the
  dense baseline, queue stalls, lane starvation (and the healthy drain
  tail that must NOT flag);
* the post-mortem path — JSONL flight-log round-trip, mixed-stream sweep
  splitting, the CLI.
"""
import json

import numpy as np

from repro.core.hybrid import ALPHA_DEFAULT, BETA_DEFAULT
from repro.graph.generator import rmat_graph
from repro.obs import (FlightSink, LayerRecord, MetricsRegistry,
                       SweepRecorder, diagnose, diagnose_log,
                       records_from_jsonl, replay_switch, split_sweeps)
from repro.obs.doctor import main as doctor_main

# ---------------------------------------------------------------------------
# oracle parity
# ---------------------------------------------------------------------------


def test_replay_switch_matches_jitted_rule():
    import jax.numpy as jnp

    from repro.core.hybrid import switch_direction
    rng = np.random.default_rng(7)
    cases = [(bool(td), int(ef), int(vf), int(eu), int(n))
             for td, ef, vf, eu, n in zip(
                 rng.integers(0, 2, 150), rng.integers(0, 10_000, 150),
                 rng.integers(0, 3_000, 150), rng.integers(0, 10_000, 150),
                 rng.integers(1, 5_000, 150))]
    # boundary cases where the float32 comparison is exact-equal
    cases += [(True, 100, 0, 1400, 1024), (False, 0, 42, 0, 1008),
              (True, 0, 0, 0, 1), (False, 0, 0, 0, 1)]
    for td, ef, vf, eu, n in cases:
        got = replay_switch(td, ef, vf, eu, n, ALPHA_DEFAULT, BETA_DEFAULT)
        ref = bool(switch_direction(
            jnp.asarray(td), jnp.asarray(ef), jnp.asarray(vf),
            jnp.asarray(eu), n, ALPHA_DEFAULT, BETA_DEFAULT))
        assert got == ref, (td, ef, vf, eu, n)


# ---------------------------------------------------------------------------
# synthetic LayerRecord traces
# ---------------------------------------------------------------------------


def _bfs_record(layer, *, slots=(), rows=(), dirs=(), vf=(), ef=(), eu=(),
                active=None, exch_bytes=0, exch_format="none"):
    active = max(1, len(slots)) if active is None else active
    mode = ("idle" if not dirs
            else "td" if set(dirs) == {0}
            else "bu" if set(dirs) == {1} else "mixed")
    return LayerRecord(
        layer=layer, engine="msbfs", kind="bfs", mode=mode,
        active_lanes=active, frontier_words=8, frontier_density=0.1,
        edges_relaxed=int(sum(np.where(np.array(dirs) == 0,
                                       ef, eu))) if dirs else 0,
        words_touched=16, exch_bytes=exch_bytes, exch_format=exch_format,
        wall_ms=0.1, slots=slots, rows=rows, dirs=dirs, vf=vf, ef=ef,
        eu=eu)


def test_seeded_mis_switch_is_flagged():
    """The acceptance pin: a recorded direction the oracle disagrees
    with is reported with its layer, slot and the wasted-edge
    estimate."""
    n, alpha, beta = 100, 2.0, 2.0
    # layer 0: ef=10 <= eu/alpha=50 -> oracle says stay TD, but the
    # trace records BU: 90 wasted edges (eu=100 inspected vs ef=10)
    # layer 1: continuing from the RECORDED direction (BU), vf=60 >=
    # n/beta=50 -> stays BU, recorded BU: agreement — one finding only,
    # the mis-switch must not cascade
    records = [
        _bfs_record(0, slots=(0,), rows=(0,), dirs=(1,), vf=(30,),
                    ef=(10,), eu=(100,)),
        _bfs_record(1, slots=(0,), rows=(1,), dirs=(1,), vf=(60,),
                    ef=(40,), eu=(80,)),
    ]
    reg = MetricsRegistry()
    rep = diagnose(records, n=n, alpha=alpha, beta=beta, registry=reg)
    assert not rep.ok()
    assert rep.decisions_audited == 2
    assert [f.kind for f in rep.findings] == ["mis_switch"]
    f = rep.findings[0]
    assert f.layer == 0 and f.slot == 0 and f.wasted_edges == 90
    assert "oracle picks TD" in f.message
    assert rep.wasted_edges() == 90
    assert "ANOMALIES" in rep.text() and "mis_switch" in rep.text()
    text = reg.expose()
    assert 'obs_doctor_findings_total{kind="mis_switch"} 1' in text
    assert "obs_doctor_decisions_total 2" in text
    # the same counters with the recorded direction corrected audit clean
    healthy = [
        _bfs_record(0, slots=(0,), rows=(0,), dirs=(0,), vf=(30,),
                    ef=(10,), eu=(100,)),
        _bfs_record(1, slots=(0,), rows=(1,), dirs=(0,), vf=(60,),
                    ef=(40,), eu=(80,)),
    ]
    assert diagnose(healthy, n=n, alpha=alpha, beta=beta).ok()


def test_healthy_scale10_sweep_audits_clean():
    """The acceptance pin: a real recorded hybrid sweep at scale 10 —
    pipelined engine, queue refills and all — replays with ZERO
    anomalies (the oracle agrees with every recorded decision by
    construction)."""
    from repro.core.msbfs import msbfs_pipelined
    g = rmat_graph(10, edgefactor=16, seed=0)
    rec = SweepRecorder(engine="msbfs")
    roots = np.arange(64, dtype=np.int32) % g.n
    msbfs_pipelined(g, roots, lanes=32, recorder=rec)
    rep = diagnose(rec.records, n=g.n)
    assert rep.decisions_audited >= roots.size   # >= one decision per root
    assert rep.ok(), rep.text()
    assert "OK — no anomalies" in rep.text()


def test_switch_audit_skips_without_context():
    records = [_bfs_record(0, slots=(0,), rows=(0,), dirs=(1,), vf=(1,),
                           ef=(1,), eu=(100,))]
    rep = diagnose(records)                       # no n: audit skipped
    assert rep.ok() and rep.decisions_audited == 0
    assert any("pass n" in note for note in rep.notes)
    rep = diagnose(records, n=100, mode="bottomup")  # forced direction
    assert rep.ok() and any("forces" in note for note in rep.notes)
    assert diagnose([]).layers == 0


def test_exchange_regression_against_dense_baseline():
    records = [
        _bfs_record(0, exch_bytes=1000, exch_format="dense"),
        _bfs_record(1, exch_bytes=400, exch_format="compressed"),
        _bfs_record(2, exch_bytes=1500, exch_format="compressed"),
    ]
    rep = diagnose(records)
    assert rep.exchange_audited
    kinds = [(f.kind, f.layer) for f in rep.findings]
    assert kinds == [("exchange_regression", 2)]
    assert rep.findings[0].detail["dense_bytes"] == 1000
    # explicit baseline overrides inference; higher baseline clears it
    assert diagnose(records, dense_bytes=1500).ok()
    # all-compressed stream with no baseline: skipped, and says so
    rep = diagnose(records[1:])
    assert not rep.exchange_audited and rep.ok()
    assert any("no dense" in note for note in rep.notes)


def test_queue_stall_and_lane_starvation():
    def occ(layer, active):
        return _bfs_record(layer, active=active)

    # a zero-active step mid-sweep is a stall; one at the very end is
    # just the sweep finishing
    rep = diagnose([occ(0, 4), occ(1, 0), occ(2, 4), occ(3, 0)])
    assert [f.kind for f in rep.findings] == ["queue_stall"]
    assert rep.findings[0].layer == 1
    # sustained low occupancy that RECOVERS is starvation...
    low_then_recover = [occ(0, 8), occ(1, 8), occ(2, 1), occ(3, 1),
                        occ(4, 1), occ(5, 8), occ(6, 8)]
    rep = diagnose(low_then_recover)
    assert [f.kind for f in rep.findings] == ["lane_starvation"]
    assert rep.findings[0].layer == 2
    assert rep.findings[0].detail["run_layers"] == 3
    # ...but the natural drain tail of a finishing sweep never flags
    drain_tail = [occ(0, 8), occ(1, 8), occ(2, 1), occ(3, 1), occ(4, 1)]
    assert diagnose(drain_tail).ok()


# ---------------------------------------------------------------------------
# flight-log surface
# ---------------------------------------------------------------------------


def _record_real_sweep(path=None):
    from repro.core.msbfs import msbfs_pipelined
    g = rmat_graph(8, edgefactor=8, seed=41)
    sink = FlightSink(path) if path else None
    rec = SweepRecorder(engine="msbfs", sink=sink)
    msbfs_pipelined(g, np.arange(12, dtype=np.int32), lanes=8,
                    recorder=rec)
    if sink:
        sink.close()
    return g, rec


def test_records_from_jsonl_round_trip(tmp_path):
    path = str(tmp_path / "flight.jsonl")
    g, rec = _record_real_sweep(path)
    back = records_from_jsonl(path)
    assert back == rec.records                  # frozen-dataclass equality
    assert diagnose(back, n=g.n).ok()


def test_split_sweeps_mixed_stream():
    a1 = [_bfs_record(i) for i in range(3)]
    a2 = [_bfs_record(i) for i in range(2)]     # layer resets -> new sweep
    b = [LayerRecord(layer=i, engine="sssp", kind="sssp", mode="light",
                     active_lanes=1, frontier_words=1,
                     frontier_density=0.5, edges_relaxed=1,
                     words_touched=1, exch_bytes=0, exch_format="none",
                     wall_ms=0.1) for i in range(2)]
    # interleave as a shared flight sink would see them
    stream = [a1[0], b[0], a1[1], b[1], a1[2], a2[0], a2[1]]
    sweeps = split_sweeps(stream)
    assert [len(s) for s in sweeps] == [3, 2, 2]
    assert sweeps[0] == a1 and sweeps[1] == a2 and sweeps[2] == b
    reports = diagnose_log(stream, n=100)
    assert len(reports) == 3
    assert {r.kind for r in reports} == {"bfs", "sssp"}
    # the sssp report notes it carries no TD/BU decision
    sssp_rep = next(r for r in reports if r.kind == "sssp")
    assert any("no TD/BU" in note for note in sssp_rep.notes)


def test_doctor_cli(tmp_path, capsys):
    path = str(tmp_path / "flight.jsonl")
    g, rec = _record_real_sweep(path)
    out = str(tmp_path / "doctor.txt")
    rc = doctor_main([path, "--n", str(g.n), "--fail-on-findings",
                      "--out", out])
    assert rc == 0
    text = capsys.readouterr().out
    assert "OK — no anomalies" in text and "0 anomalies" in text
    with open(out) as f:
        assert "OK — no anomalies" in f.read()
    # a corrupt flight log (mis-switched layer injected) exits nonzero
    bad = str(tmp_path / "bad.jsonl")
    with open(path) as f:
        lines = [json.loads(ln) for ln in f]
    flipped = False
    for ln in lines:
        if not flipped and ln["dirs"]:
            ln["dirs"] = [1 - d for d in ln["dirs"]]
            flipped = True
    assert flipped
    with open(bad, "w") as f:
        for ln in lines:
            f.write(json.dumps(ln) + "\n")
    rc = doctor_main([bad, "--n", str(g.n), "--fail-on-findings"])
    assert rc == 1
    assert "mis_switch" in capsys.readouterr().out
    # --json emits the structured report
    rc = doctor_main([path, "--n", str(g.n), "--json"])
    assert rc == 0
    payload = capsys.readouterr().out
    doc = json.loads(payload[:payload.rindex("]") + 1])
    assert doc and doc[0]["counts"] == {}


def test_finding_and_report_dict_views():
    records = [_bfs_record(0, slots=(0,), rows=(0,), dirs=(1,), vf=(1,),
                           ef=(1,), eu=(50,))]
    rep = diagnose(records, n=1000, alpha=2.0, beta=2.0)
    d = rep.as_dict()
    assert d["counts"] == {"mis_switch": 1}
    assert d["findings"][0]["kind"] == "mis_switch"
    assert json.dumps(d)                         # JSON-clean
