"""Model-layer unit tests: attention equivalences, MoE dispatch, GNN
equivariance, spherical harmonics, DIEN."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models.moe import MoEConfig, moe_ffn, moe_ffn_dense_ref, moe_init
from repro.models.transformer import (LMConfig, init_lm, lm_decode_step,
                                      lm_forward, lm_prefill)


def _rot(a, b, c):
    Rz = np.array([[np.cos(a), -np.sin(a), 0], [np.sin(a), np.cos(a), 0],
                   [0, 0, 1]])
    Ry = np.array([[np.cos(b), 0, np.sin(b)], [0, 1, 0],
                   [-np.sin(b), 0, np.cos(b)]])
    Rx = np.array([[1, 0, 0], [0, np.cos(c), -np.sin(c)],
                   [0, np.sin(c), np.cos(c)]])
    return (Rz @ Ry @ Rx).astype(np.float32)


# ----------------------------------------------------------------- attention


@pytest.mark.parametrize("b,sq,hq,hkv,dh", [(2, 256, 8, 2, 32),
                                            (1, 512, 4, 4, 16)])
def test_chunked_attention_equals_naive(b, sq, hq, hkv, dh):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, hq, dh))
    k = jax.random.normal(ks[1], (b, sq, hkv, dh))
    v = jax.random.normal(ks[2], (b, sq, hkv, dh))
    a = L.gqa_attention(q, k, v, causal=True)
    c = L.gqa_attention_chunked(q, k, v, causal=True, q_chunk=64,
                                kv_chunk=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-4,
                               atol=2e-5)


def test_rope_relative_property():
    """RoPE: <q_i, k_j> depends only on i - j."""
    dh = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, dh))
    def dot_at(i, j):
        qi = L.apply_rope(q, jnp.asarray([[i]]), 10000.0)
        kj = L.apply_rope(k, jnp.asarray([[j]]), 10000.0)
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3


def test_decode_matches_forward():
    cfg = LMConfig(name="t", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                   d_head=16, d_ff=128, vocab=256)
    params, _ = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
    logits_p, cache = lm_prefill(params, toks, cfg)
    logits_f, _ = lm_forward(params, toks, cfg)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(logits_f[:, -1]), rtol=2e-4,
                               atol=2e-4)
    cache = tuple(jnp.pad(c, ((0, 0), (0, 0), (0, 8), (0, 0), (0, 0)))
                  for c in cache)
    nxt = jax.random.randint(jax.random.PRNGKey(2), (2, 1), 0, 256)
    logits_d, _ = lm_decode_step(params, nxt, cache, jnp.int32(16), cfg)
    logits_f2, _ = lm_forward(params, jnp.concatenate([toks, nxt], 1), cfg)
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(logits_f2[:, -1]), rtol=2e-4,
                               atol=2e-4)


def test_fp8_kv_cache_decode_close():
    cfg = LMConfig(name="t8", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                   d_head=16, d_ff=128, vocab=128,
                   kv_cache_dtype="float8_e4m3fn")
    params, _ = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 128)
    logits_p, cache = lm_prefill(params, toks, cfg)
    assert cache[0].dtype == jnp.float8_e4m3fn
    cache = tuple(jnp.pad(c, ((0, 0), (0, 0), (0, 4), (0, 0), (0, 0)))
                  for c in cache)
    nxt = jax.random.randint(jax.random.PRNGKey(2), (2, 1), 0, 128)
    logits_d, _ = lm_decode_step(params, nxt, cache, jnp.int32(12), cfg)
    logits_f, _ = lm_forward(params, jnp.concatenate([toks, nxt], 1), cfg)
    # fp8 storage: close but not exact
    corr = np.corrcoef(np.asarray(logits_d).ravel(),
                       np.asarray(logits_f[:, -1]).ravel())[0, 1]
    assert corr > 0.98


# ----------------------------------------------------------------------- MoE


def test_moe_dispatch_matches_dense_ref():
    cfg = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                    capacity_factor=4.0)
    p, _ = moe_init(jax.random.PRNGKey(3), 64, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (96, 64))
    y1, aux = moe_ffn(p, x, cfg)
    y2 = moe_ffn_dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=3e-4,
                               atol=3e-5)
    assert float(aux) >= 0


def test_moe_capacity_drops_are_bounded():
    cfg = MoEConfig(num_experts=4, top_k=1, d_ff_expert=16,
                    capacity_factor=0.5)   # force drops
    p, _ = moe_init(jax.random.PRNGKey(3), 32, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (64, 32))
    y, _ = moe_ffn(p, x, cfg)
    # dropped tokens produce zero output rows, never NaN
    assert np.isfinite(np.asarray(y)).all()


# ----------------------------------------------------------------------- GNN


def test_sph_orthonormal_and_gaunt():
    from repro.models.gnn.sph import check_orthonormal, gaunt_tensor
    assert check_orthonormal() < 1e-10
    g = gaunt_tensor()
    np.testing.assert_allclose(g, np.transpose(g, (1, 0, 2)), atol=1e-12)
    np.testing.assert_allclose(g[0], np.eye(9) * g[0, 0, 0], atol=1e-10)


def test_egnn_equivariance():
    from repro.models.gnn.common import synthetic_graph_batch
    from repro.models.gnn.egnn import EGNNConfig, egnn_forward, init_egnn
    gb = synthetic_graph_batch(jax.random.PRNGKey(0), 60, 200, 16, n_graphs=2)
    R = jnp.asarray(_rot(0.3, 1.1, -0.7))
    gb_rot = gb._replace(pos=gb.pos @ R.T + 2.5)
    cfg = EGNNConfig(d_feat=16, d_hidden=32)
    p, _ = init_egnn(jax.random.PRNGKey(3), cfg)
    h1, x1, e1 = egnn_forward(p, gb, cfg)
    h2, x2, e2 = egnn_forward(p, gb_rot, cfg)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(x1 @ R.T + 2.5), np.asarray(x2),
                               rtol=1e-3, atol=1e-3)


def test_mace_equivariance():
    from repro.models.gnn.common import synthetic_graph_batch
    from repro.models.gnn.mace import MACEConfig, init_mace, mace_forward
    gb = synthetic_graph_batch(jax.random.PRNGKey(0), 60, 200, 16, n_graphs=2)
    R = jnp.asarray(_rot(0.5, -0.9, 0.4))
    gb_rot = gb._replace(pos=gb.pos @ R.T - 1.5)
    cfg = MACEConfig(d_feat=16, d_hidden=16)
    p, _ = init_mace(jax.random.PRNGKey(4), cfg)
    H1, e1 = mace_forward(p, gb, cfg)
    H2, e2 = mace_forward(p, gb_rot, cfg)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-4)
    for sl in (slice(1, 4), slice(4, 9)):
        n1 = np.linalg.norm(np.asarray(H1[:, :, sl]), axis=-1)
        n2 = np.linalg.norm(np.asarray(H2[:, :, sl]), axis=-1)
        np.testing.assert_allclose(n1, n2, rtol=1e-3, atol=1e-5)


def test_gnn_grads_flow():
    from repro.models.gnn.common import synthetic_graph_batch
    from repro.models.gnn.gcn import GCNConfig, gcn_loss, init_gcn
    gb = synthetic_graph_batch(jax.random.PRNGKey(0), 100, 400, 8,
                               n_classes=4)
    cfg = GCNConfig(d_feat=8, n_classes=4)
    p, _ = init_gcn(jax.random.PRNGKey(1), cfg)
    g = jax.grad(lambda pp: gcn_loss(pp, gb, cfg)[0])(p)
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(g))
    assert any(float(jnp.abs(x).sum()) > 0 for x in jax.tree.leaves(g))


# ---------------------------------------------------------------------- DIEN


def test_dien_augru_attention_effect():
    """Zero attention on history -> final interest is the zero init state."""
    from repro.models.recsys.dien import DIENConfig, _evolution, init_dien
    cfg = DIENConfig(n_items=100, n_cats=5, n_profiles=10, seq_len=4)
    p, _ = init_dien(jax.random.PRNGKey(0), cfg)
    b, t = 3, 4
    states = jax.random.normal(jax.random.PRNGKey(1), (b, t, cfg.gru_dim))
    behav = jax.random.normal(jax.random.PRNGKey(2), (b, t, cfg.behav_dim))
    target = jax.random.normal(jax.random.PRNGKey(3), (b, cfg.behav_dim))
    mask = jnp.zeros((b, t), bool)   # nothing valid -> h stays 0
    hT = _evolution(p, states, behav, target, mask, cfg)
    np.testing.assert_allclose(np.asarray(hT), 0.0, atol=1e-6)


def test_embedding_bag_mean_sum():
    from repro.models.recsys.dien import embedding_bag
    table = jnp.asarray(np.arange(20, dtype=np.float32).reshape(10, 2))
    ids = jnp.asarray([[1, 2, 3], [4, 4, 0]])
    mask = jnp.asarray([[True, True, False], [True, False, False]])
    s = embedding_bag(table, ids, mask, op="sum")
    np.testing.assert_allclose(np.asarray(s),
                               [[2 + 4, 3 + 5], [8, 9]])
    m = embedding_bag(table, ids, mask, op="mean")
    np.testing.assert_allclose(np.asarray(m), [[3, 4], [8, 9]])
