# Single entry point for CI and future PRs.
#
#   make test         tier-1 suite (the ROADMAP verify command)
#   make bench-smoke  MS-BFS batched-vs-serial TEPS at a small scale
#   make bench        the same at the paper-protocol scale 14

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-smoke bench

test:
	$(PYTHON) -m pytest -x -q

bench-smoke:
	$(PYTHON) benchmarks/msbfs_teps.py --scale 10

bench:
	$(PYTHON) benchmarks/msbfs_teps.py --scale 14
