# Single entry point for CI and future PRs.
#
#   make test             tier-1 suite (the ROADMAP verify command)
#   make test-properties  hypothesis MS-BFS property suite, fixed seed /
#                         bounded examples (derandomized -> reproducible)
#   make bench-smoke      MS-BFS TEPS curve (R=64/128/256) at a small scale
#   make bench            the same at the paper-protocol scale 14

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-properties bench-smoke bench

test:
	$(PYTHON) -m pytest -x -q

test-properties:
	MSBFS_PROP_EXAMPLES=25 $(PYTHON) -m pytest \
	    tests/test_msbfs_properties.py tests/test_validate.py -q

bench-smoke:
	$(PYTHON) benchmarks/msbfs_teps.py --scale 10

bench:
	$(PYTHON) benchmarks/msbfs_teps.py --scale 14
