# Single entry point for CI and future PRs.
#
#   make test             tier-1 suite (the ROADMAP verify command)
#   make test-properties  hypothesis MS-BFS property suite, fixed seed /
#                         bounded examples (derandomized -> reproducible)
#   make test-dist        distributed suites under 4 forced host devices
#   make bench-smoke      MS-BFS TEPS curve (R=64/128/256) at a small scale
#   make bench            the same at the paper-protocol scale 14
#   make bench-dist       sharded MS-BFS scaling curve (ndev 1/2/4)
#   make bench-dist2d     2-D grid MS-BFS: TEPS + bytes-exchanged-per-layer
#                         for dense vs compressed frontier wire formats
#   make bench-analytics  analytics workloads (components/closeness/khop)
#                         TEPS-equivalent throughput on the lane engine
#   make bench-sssp       weighted-path workloads (delta-stepping SSSP /
#                         weighted closeness) on the tropical lane engine
#   make bench-dist-sssp  sharded delta-stepping SSSP: TEPS-equivalents +
#                         bytes-exchanged-per-step, dense vs compressed
#   make bench-serve      AnalyticsService replay: streamed-vs-flush trace,
#                         mix TEPS + p50/p99 sojourn + early-answer gain
#   make trace-smoke      mixed-workload serve run -> out/sweep_trace.json
#                         (Perfetto-loadable) + out/sweep_metrics.txt scrape
#   make serve-live       live HTTP plane at scale 10: /metrics, /healthz,
#                         /v1 wire transport, flight log + doctor report
#                         under out/ (Ctrl-C to stop)
#   make ci-bench         fast benches -> BENCH_pr.json + regression gate
#   make lint             ruff check + format check (rule set: ruff.toml)

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-properties test-dist bench-smoke bench bench-dist \
        bench-dist2d bench-analytics bench-sssp bench-dist-sssp \
        bench-serve trace-smoke serve-live ci-bench lint

test:
	$(PYTHON) -m pytest -x -q

test-properties:
	MSBFS_PROP_EXAMPLES=25 $(PYTHON) -m pytest \
	    tests/test_msbfs_properties.py tests/test_sssp_properties.py \
	    tests/test_compression_properties.py tests/test_validate.py -q

test-dist:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 $(PYTHON) -m pytest \
	    tests/test_dist_bfs.py tests/test_dist_msbfs.py tests/test_dist2d.py \
	    tests/test_dist_sssp.py \
	    tests/test_analytics.py::test_analytics_ndev2_parity \
	    tests/test_serving.py::test_serving_dist_streaming_parity -q

bench-smoke:
	$(PYTHON) benchmarks/msbfs_teps.py --scale 10

bench:
	$(PYTHON) benchmarks/msbfs_teps.py --scale 14

bench-dist:
	$(PYTHON) benchmarks/dist_msbfs_teps.py --scale 12

bench-dist2d:
	$(PYTHON) benchmarks/dist2d_teps.py --scale 12

bench-analytics:
	$(PYTHON) benchmarks/analytics_bench.py --scale 12

bench-sssp:
	$(PYTHON) benchmarks/sssp_bench.py --scale 12

bench-dist-sssp:
	$(PYTHON) benchmarks/dist_sssp_teps.py --scale 12

bench-serve:
	$(PYTHON) benchmarks/serve_bench.py --scale 12

trace-smoke:
	$(PYTHON) examples/sweep_trace.py

serve-live:
	mkdir -p out
	$(PYTHON) -m repro.launch.serve_bfs --scale 10 --lanes 32 \
	    --queries 24 --mix bfs:3,khop:2,reach:1,sssp:1 --listen 8321 \
	    --serve-seconds 3600 --flight-out out/flight.jsonl \
	    --doctor-out out/doctor.txt --slo-p99 500

ci-bench:
	$(PYTHON) benchmarks/ci_bench.py --out BENCH_pr.json \
	    --baseline BENCH_baseline.json --tolerance 0.25 \
	    --history BENCH_history.jsonl

lint:
	ruff check .
	ruff format --check .
